//! Task-level event-driven simulation with hardware-consistent contention
//! resolution (paper §6).
//!
//! ## Semantics
//!
//! * An *event* is a task completion; it fires ticks on the task's output
//!   edges. A task activates (becomes ready) for iteration `i` when every
//!   input edge holds a tick for `i`; its ready time is the max tick
//!   timestamp (Eq. 1).
//! * **Compute points are exclusive**: one task at a time, FIFO by ready
//!   time, `Start(v) = max(ticks, t_current)`, `End(v) = Start + E_p(v)`,
//!   and the point's timer advances to `End(v)` (Eq. 1).
//! * **Communication / memory / DRAM points are shared**: concurrent flows
//!   progress under processor sharing. A flow's instantaneous rate is
//!   `1 / congestion` where congestion is the maximum number of flows
//!   sharing any physical link it occupies ([`super::links`]); flows
//!   without route information (and all flows on memory/DRAM channels)
//!   share the whole resource. Rates are recomputed at every arrival and
//!   departure — this is the fixed point that the paper's Algorithm 1
//!   (contention zones + truncation + contention-staged buffer with
//!   commit/rollback) converges to, computed here by processing events in
//!   global time order. [`super::consistent`] implements the speculative
//!   per-point Algorithm 1 itself; the two engines agree (see its tests),
//!   while the naive baseline in [`super::reference`] reproduces the
//!   paper's Fig. 6 inconsistency.
//! * **Storage tasks** activate at the first input tick (Eq. 2 `Start`),
//!   immediately provide ticks on their output edges, occupy their memory's
//!   capacity while active, and deactivate when the last dependent task
//!   completes (Eq. 2 `End`).
//! * **Sync tasks** sharing a `sync_id` form a barrier: all complete at the
//!   max of their ready times.
//! * Batches stream through the graph: `SimConfig::iterations` ticks carry
//!   iteration numbers (§6.1); a task evaluates once per iteration.
//!
//! ## Incremental contention tracking
//!
//! The hot loop of a contended simulation is the per-event rate update.
//! Instead of rebuilding a link-occupancy histogram from scratch at every
//! arrival/departure, the engine interns each routed flow's link set once
//! at setup ([`super::links::RouteTable`]), remaps link ids to dense
//! per-point indices, and maintains a flat occupancy counter array with
//! ±1 deltas as flows come and go. Each flow carries its current
//! *bottleneck* (max occupancy over its links); only flows whose
//! bottleneck can have changed are re-derived, and the per-event rate pass
//! is a flat O(flows) sweep with no hashing or allocation. Setting
//! [`SimConfig::incremental`] to `false` falls back to a full per-event
//! recompute; both paths are bit-identical (golden-tested) and the
//! incremental invariants are cross-checked by debug assertions.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};
use std::sync::Arc;

use crate::eval::Registry;
use crate::hwir::{Hardware, PointId};
use crate::mapping::Mapping;
use crate::taskgraph::{Executor, StaticExecutor, TaskGraph, TaskId, TaskKind};
use crate::util::densemap::DenseMap;

use super::links::RouteTable;

/// Simulation time in cycles (fractional under bandwidth sharing).
pub type Time = f64;

/// Total-ordered f64 for the event queue.
#[derive(Debug, Clone, Copy, PartialEq)]
struct OrdF64(f64);
impl Eq for OrdF64 {}
impl PartialOrd for OrdF64 {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for OrdF64 {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.total_cmp(&other.0)
    }
}

/// Simulator configuration.
#[derive(Debug, Clone)]
pub struct SimConfig {
    /// Number of input batches streamed through the graph.
    pub iterations: u32,
    /// Record a per-task execution timeline.
    pub collect_timeline: bool,
    /// Memoize evaluator demands by (descriptor, point) — the
    /// representative-task deduplication of §7.2.
    pub dedup: bool,
    /// Safety cap on processed events.
    pub max_events: u64,
    /// Deterministic per-simulation event budget (`0` = off). Unlike
    /// [`SimConfig::max_events`] — a last-resort safety net sized far
    /// beyond any legitimate run — this is the *deadline* knob exploration
    /// sets to bound a single candidate: exceeding it fails the
    /// simulation with a "deadline exceeded" error, which the DSE engine
    /// records as the candidate's [`Evaluation::error`](crate::dse::explore::Evaluation)
    /// instead of hanging a worker. Event counts are deterministic, so
    /// the same config fails the same candidates on every machine.
    pub deadline_events: u64,
    /// Wall-clock backstop in milliseconds (`0` = off), checked every few
    /// thousand events. Catches pathologies the event budget cannot see
    /// (e.g. an evaluator stuck between events). Nondeterministic by
    /// nature — use `deadline_events` where reproducibility matters.
    pub deadline_ms: u64,
    /// Use the incremental contention tracker (±1 link-occupancy deltas;
    /// only flows whose bottleneck count changed are re-derived). `false`
    /// falls back to the full per-event recompute. Both paths produce
    /// bit-identical [`SimResult`]s — the flag exists for cross-checking
    /// and regression triage, not for accuracy trade-offs.
    pub incremental: bool,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            iterations: 1,
            collect_timeline: false,
            dedup: true,
            max_events: 500_000_000,
            deadline_events: 0,
            deadline_ms: 0,
            incremental: true,
        }
    }
}

/// One timeline record (with `collect_timeline`).
#[derive(Debug, Clone, PartialEq)]
pub struct TimelineEvent {
    pub task: TaskId,
    pub iter: u32,
    pub point: PointId,
    pub start: Time,
    pub end: Time,
}

/// Simulation output. `PartialEq` supports the golden tests pinning
/// bit-identical results across the incremental and full-recompute
/// contention paths. The per-task/per-point maps are dense `Vec`-backed
/// maps ([`DenseMap`]) with stable index-order iteration — no per-result
/// hashing, and derived artifacts (e.g. `memory_violations`) come out in
/// a deterministic order.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SimResult {
    /// Completion time of the last task (cycles).
    pub makespan: Time,
    /// (start, end) of each task's final iteration.
    pub timings: DenseMap<TaskId, (Time, Time)>,
    /// Busy cycles per point (service demand actually delivered).
    pub point_busy: DenseMap<PointId, f64>,
    /// Completed (task, iteration) evaluations.
    pub completed: u64,
    /// Tasks that never ran all iterations (blocked or untriggered).
    pub unfinished: u64,
    /// Flow-rate recomputation events where a flow lost bandwidth — the
    /// engine analogue of Algorithm 1 truncations.
    pub truncations: u64,
    /// Contention-staged-buffer rollbacks (only the speculative
    /// [`super::consistent`] scheduler produces these; the global-order
    /// engine never needs to roll back).
    pub rollbacks: u64,
    /// Energy delivered per point (pJ), from the evaluator energy model.
    pub point_energy: DenseMap<PointId, f64>,
    /// Peak bytes resident per memory point.
    pub peak_memory: DenseMap<PointId, u64>,
    /// Capacity violations ("point, peak, capacity").
    pub memory_violations: Vec<String>,
    /// Timeline (only with `collect_timeline`).
    pub timeline: Vec<TimelineEvent>,
}

impl SimResult {
    /// Utilization of a point in [0,1].
    pub fn utilization(&self, point: PointId) -> f64 {
        if self.makespan <= 0.0 {
            return 0.0;
        }
        self.point_busy.get(&point).copied().unwrap_or(0.0) / self.makespan
    }

    /// Total energy across all points (pJ).
    pub fn total_energy(&self) -> f64 {
        self.point_energy.values().sum()
    }

    /// Average power in W assuming `freq_ghz` clocking (pJ/cycle ≙ mW at
    /// 1 GHz).
    pub fn avg_power_w(&self, freq_ghz: f64) -> f64 {
        if self.makespan <= 0.0 {
            return 0.0;
        }
        self.total_energy() / self.makespan * freq_ghz * 1e-3
    }
}

/// Simulation error.
#[derive(Debug)]
pub struct SimError(pub String);

impl std::fmt::Display for SimError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "simulation error: {}", self.0)
    }
}
impl std::error::Error for SimError {}

// ---------------------------------------------------------------------
// Engine internals
// ---------------------------------------------------------------------

#[derive(Debug)]
enum Event {
    /// Task `0` ready for iteration `1`.
    Arrival(TaskId, u32),
    /// Exclusive point finished its running task (validity via generation).
    ExclDone(PointId, u64),
    /// Candidate completion on a shared point (validity via generation).
    FlowDone(PointId, u64),
}

#[derive(Debug)]
struct Flow {
    task: TaskId,
    iter: u32,
    /// Remaining shareable work (cycles at full rate).
    remaining: f64,
    /// Initial shareable work; completion tolerance scales with it.
    total: f64,
    /// Fixed latency appended after the transfer completes.
    fixed: f64,
    /// `(offset, len)` span of dense link indices in the route table;
    /// `len == 0` = shares the whole resource.
    links: (u32, u32),
    /// Max occupancy over the flow's links (incrementally maintained;
    /// meaningless for whole-resource flows).
    bottleneck: u32,
    /// Current progress rate in (0, 1].
    rate: f64,
    start: Time,
}

/// Completion tolerance for a flow of `total` work checked at time `now`.
///
/// Two failure modes of a fixed absolute epsilon: (1) a very large
/// transfer's accumulated integration error (`remaining -= rate * dt`)
/// scales with its work, so the residual can exceed the epsilon; (2) a
/// residual below ~ulp(`now`) makes the retry completion time round back
/// to `now`, respawning zero-length FlowDone events forever. The size
/// term covers (1); the time term covers (2) with a few ulps of headroom
/// — since `rate <= 1`, any residual above it yields a retry step that
/// strictly advances time, so at most one extra event fires instead of a
/// spin, and genuinely small flows late in long simulations are not
/// swallowed. Shared with the Algorithm-1 scheduler, whose zone loop has
/// the same failure modes (and no event cap).
pub(super) fn completion_eps(total: f64, now: Time) -> f64 {
    let size = 1e-9 * total.max(1.0);
    let time = 4.0 * f64::EPSILON * now;
    size.max(time)
}

#[derive(Debug, Default)]
struct SharedPoint {
    flows: Vec<Flow>,
    /// Per dense link: number of flows occupying it (incremental mode).
    occupancy: Vec<u32>,
    /// Per dense link: indices into `flows` of its occupants (incremental
    /// mode's reverse index for targeted bottleneck repair).
    link_flows: Vec<Vec<u32>>,
    /// Flows sharing the whole resource (no link information).
    universal: u32,
    last_update: Time,
    generation: u64,
}

impl SharedPoint {
    /// Reset for a fresh simulation with `num_links` dense links, keeping
    /// every allocation (flow vec, occupancy array, reverse index) that is
    /// already the right shape.
    fn reset(&mut self, num_links: usize) {
        self.flows.clear();
        self.occupancy.clear();
        self.occupancy.resize(num_links, 0);
        self.link_flows.truncate(num_links);
        for lf in &mut self.link_flows {
            lf.clear();
        }
        while self.link_flows.len() < num_links {
            self.link_flows.push(Vec::new());
        }
        self.universal = 0;
        self.last_update = 0.0;
        self.generation = 0;
    }

    /// Register a flow; in incremental mode, bump its links' occupancy and
    /// raise the bottleneck of every flow sharing a bumped link.
    fn add_flow_entry(&mut self, flow: Flow, routes: &RouteTable, incremental: bool) {
        let idx = self.flows.len() as u32;
        let (off, len) = flow.links;
        self.flows.push(flow);
        if !incremental {
            return;
        }
        if len == 0 {
            self.universal += 1;
            return;
        }
        let mut bottleneck = 1u32;
        for &l in routes.span(off, len) {
            let li = l as usize;
            self.occupancy[li] += 1;
            let occ = self.occupancy[li];
            for &fi in &self.link_flows[li] {
                let fb = &mut self.flows[fi as usize].bottleneck;
                if occ > *fb {
                    *fb = occ;
                }
            }
            self.link_flows[li].push(idx);
            if occ > bottleneck {
                bottleneck = occ;
            }
        }
        self.flows[idx as usize].bottleneck = bottleneck;
    }

    /// Unregister and return the flow at `i`; in incremental mode, drop its
    /// links' occupancy and re-derive the bottleneck only of flows whose
    /// bottleneck sat exactly on a decremented link. `scratch` is a reused
    /// buffer of flow indices needing re-derivation.
    fn remove_flow_entry(
        &mut self,
        i: usize,
        routes: &RouteTable,
        incremental: bool,
        scratch: &mut Vec<u32>,
    ) -> Flow {
        if incremental {
            let (off, len) = self.flows[i].links;
            if len == 0 {
                self.universal -= 1;
            } else {
                scratch.clear();
                for &l in routes.span(off, len) {
                    let li = l as usize;
                    let pos = self.link_flows[li]
                        .iter()
                        .position(|&x| x == i as u32)
                        .expect("flow registered on its link");
                    self.link_flows[li].swap_remove(pos);
                    self.occupancy[li] -= 1;
                    let old_occ = self.occupancy[li] + 1;
                    for &fi in &self.link_flows[li] {
                        if self.flows[fi as usize].bottleneck == old_occ {
                            scratch.push(fi);
                        }
                    }
                }
                // a survivor sharing several decremented links gets marked
                // once per link — re-derive each at most once
                scratch.sort_unstable();
                scratch.dedup();
                for &fi in scratch.iter() {
                    let (o2, l2) = self.flows[fi as usize].links;
                    let mut worst = 1u32;
                    for &l in routes.span(o2, l2) {
                        worst = worst.max(self.occupancy[l as usize]);
                    }
                    self.flows[fi as usize].bottleneck = worst;
                }
            }
        }
        let last = self.flows.len() - 1;
        let flow = self.flows.swap_remove(i);
        if incremental && i < last {
            // the flow formerly at `last` now sits at `i`: repair the
            // reverse index
            let (off, len) = self.flows[i].links;
            for &l in routes.span(off, len) {
                for x in self.link_flows[l as usize].iter_mut() {
                    if *x == last as u32 {
                        *x = i as u32;
                        break;
                    }
                }
            }
        }
        flow
    }

    /// Debug cross-check: the incrementally maintained occupancy, reverse
    /// index, universal count and per-flow bottlenecks must match a from-
    /// scratch recompute.
    #[cfg(debug_assertions)]
    fn assert_consistent(&self, routes: &RouteTable) {
        let mut occ = vec![0u32; self.occupancy.len()];
        let mut uni = 0u32;
        for f in &self.flows {
            let (off, len) = f.links;
            if len == 0 {
                uni += 1;
            } else {
                for &l in routes.span(off, len) {
                    occ[l as usize] += 1;
                }
            }
        }
        debug_assert_eq!(uni, self.universal, "universal-flow count drifted");
        debug_assert_eq!(occ, self.occupancy, "link occupancy drifted");
        for (li, lf) in self.link_flows.iter().enumerate() {
            debug_assert_eq!(
                lf.len() as u32,
                occ[li],
                "reverse index size drifted on link {li}"
            );
        }
        for f in &self.flows {
            let (off, len) = f.links;
            if len > 0 {
                let worst = routes
                    .span(off, len)
                    .iter()
                    .map(|&l| occ[l as usize])
                    .max()
                    .unwrap_or(1);
                debug_assert_eq!(worst, f.bottleneck, "bottleneck of {} drifted", f.task);
            }
        }
    }
}

#[derive(Debug, Default)]
struct ExclPoint {
    timer: Time,
    running: Option<(TaskId, u32, Time, Time)>, // task, iter, start, end
    pending: BinaryHeap<Reverse<(OrdF64, TaskId, u32)>>,
    generation: u64,
}

impl ExclPoint {
    fn reset(&mut self) {
        self.timer = 0.0;
        self.running = None;
        self.pending.clear();
        self.generation = 0;
    }
}

#[derive(Debug, Default)]
struct StorageState {
    resident: bool,
    bytes: u64,
    start: Time,
    consumers_left: u64,
    last_consumer_end: Time,
}

struct SyncGroupState {
    members: Vec<TaskId>,
    /// per-iteration (ready_count, max_ready)
    progress: HashMap<u32, (usize, Time)>,
}

/// Every growable buffer the engine needs, kept between runs by a
/// [`SimSession`] so back-to-back simulations reuse allocations (and —
/// when the caller vouches for a shared setup via [`SimSetup::key`] — the
/// per-(descriptor, point) demand cache) instead of rebuilding them.
#[derive(Default)]
struct Arena {
    events: BinaryHeap<Reverse<(OrdF64, u64, u32)>>,
    event_payload: Vec<Event>,
    shared: Vec<SharedPoint>,
    excl: Vec<ExclPoint>,
    storage: Vec<Option<StorageState>>,
    deps_left: Vec<u32>,
    ready_time: Vec<Time>,
    real_ticks: Vec<u32>,
    done_iters: Vec<u32>,
    point_of: Vec<Option<PointId>>,
    enabled_in_deg: Vec<u32>,
    demand_memo: Vec<Option<(crate::eval::Demand, f64)>>,
    demand_cache: HashMap<(u64, u64, u64, u32), (crate::eval::Demand, f64)>,
    flat_timings: Vec<(Time, Time)>,
    mem_usage: Vec<u64>,
    flow_scratch: Vec<u32>,
    succ_scratch: Vec<TaskId>,
    dead_scratch: Vec<TaskId>,
    finished_scratch: Vec<Flow>,
    /// Setup key the demand cache was filled under (`None` = stale).
    demand_token: Option<u64>,
}

/// A prebuilt, shareable simulation setup.
///
/// `routes` is the interned [`RouteTable`] of a fixed (hardware, graph,
/// comm-task placement) triple — built once and shared across every
/// candidate on that topology instead of re-derived per simulation. `key`
/// is a caller-chosen identity for the setup: simulations carrying the
/// same key on the same [`SimSession`] keep the (task descriptor, point)
/// demand cache warm across candidates. Only pass equal keys for
/// simulations on the same hardware with the same evaluator registry.
#[derive(Debug, Clone, Default)]
pub struct SimSetup {
    pub routes: Option<Arc<RouteTable>>,
    pub key: Option<u64>,
}

/// Reusable simulation context (the engine's `reset`/re-entry path).
///
/// One session per evaluation thread: each [`SimSession::simulate`] run
/// borrows the session's arena — event heap, per-point contention
/// state, flat (task, iter) tables, scratch buffers — resets it in place,
/// and returns it when done, so thousands of back-to-back candidate
/// simulations allocate once instead of once per candidate. Results are
/// bit-identical to the stateless [`simulate`] entry point.
#[derive(Default)]
pub struct SimSession {
    arena: Arena,
}

impl SimSession {
    pub fn new() -> SimSession {
        SimSession::default()
    }

    /// Simulate with this session's reusable buffers (no shared setup).
    pub fn simulate(
        &mut self,
        hw: &Hardware,
        graph: &TaskGraph,
        mapping: &Mapping,
        evals: &Registry,
        cfg: &SimConfig,
    ) -> Result<SimResult, SimError> {
        self.simulate_prepared(hw, graph, mapping, evals, cfg, &SimSetup::default())
    }

    /// Simulate against a shared, prebuilt [`SimSetup`].
    pub fn simulate_prepared(
        &mut self,
        hw: &Hardware,
        graph: &TaskGraph,
        mapping: &Mapping,
        evals: &Registry,
        cfg: &SimConfig,
        setup: &SimSetup,
    ) -> Result<SimResult, SimError> {
        // Take the arena out for the run: an error (or a panic unwinding
        // through an evaluator) simply discards it, and the next call
        // starts from a fresh default instead of inheriting torn state.
        let arena = std::mem::take(&mut self.arena);
        let engine = Engine::new(hw, graph, mapping, evals, cfg, setup, arena)?;
        let (result, arena) = engine.run(&mut StaticExecutor)?;
        self.arena = arena;
        Ok(result)
    }
}

/// Run a simulation with the static executor.
pub fn simulate(
    hw: &Hardware,
    graph: &TaskGraph,
    mapping: &Mapping,
    evals: &Registry,
    cfg: &SimConfig,
) -> Result<SimResult, SimError> {
    simulate_dynamic(hw, graph, mapping, evals, cfg, &mut StaticExecutor)
}

/// Run a simulation with a dynamic-workload executor (§6.1 online mode).
pub fn simulate_dynamic(
    hw: &Hardware,
    graph: &TaskGraph,
    mapping: &Mapping,
    evals: &Registry,
    cfg: &SimConfig,
    executor: &mut dyn Executor,
) -> Result<SimResult, SimError> {
    let setup = SimSetup::default();
    let engine = Engine::new(hw, graph, mapping, evals, cfg, &setup, Arena::default())?;
    engine.run(executor).map(|(result, _arena)| result)
}

struct Engine<'a> {
    hw: &'a Hardware,
    graph: &'a TaskGraph,
    mapping: &'a Mapping,
    evals: &'a Registry,
    cfg: &'a SimConfig,

    events: BinaryHeap<Reverse<(OrdF64, u64, u32)>>, // (time, seq) -> event idx? see push
    event_payload: Vec<Event>,
    seq: u64,

    /// Dense per-point shared/exclusive state (indexed by `PointId`).
    shared: Vec<SharedPoint>,
    excl: Vec<ExclPoint>,
    /// Dense per-task storage residency state (indexed by `TaskId`).
    storage: Vec<Option<StorageState>>,
    syncs: HashMap<u32, SyncGroupState>,

    /// Interned, densely remapped per-(task, point) link sets — either
    /// taken from a shared [`SimSetup`] or built for this run.
    routes: Arc<RouteTable>,

    /// Flat (task, iter) tables: index = task.index() * iterations + iter.
    /// deps_left uses u32::MAX as the "uninitialized" sentinel.
    deps_left: Vec<u32>,
    ready_time: Vec<Time>,
    /// Real (non-phantom) ticks received per (task, iter) — a task whose
    /// inputs are all dead-branch phantoms is dead itself (§6.1 dynamic
    /// workloads: untriggered successors must not block joins).
    real_ticks: Vec<u32>,
    /// task -> completed iterations.
    done_iters: Vec<u32>,
    /// task -> mapped point (precomputed from the mapping for O(1) access).
    point_of: Vec<Option<PointId>>,
    /// task -> count of enabled predecessors (precomputed).
    enabled_in_deg: Vec<u32>,

    /// task -> memoized (demand, energy); first fill goes through the
    /// §7.2 representative-descriptor dedup map below. Only used with
    /// `cfg.dedup` (without it every activation re-evaluates, as before).
    demand_memo: Vec<Option<(crate::eval::Demand, f64)>>,
    demand_cache: HashMap<(u64, u64, u64, u32), (crate::eval::Demand, f64)>,
    /// Setup key guarding cross-run reuse of `demand_cache` (see
    /// [`SimSetup::key`]).
    demand_token: Option<u64>,

    /// Flat (start, end) per task, NaN = never ran; folded into the result
    /// map at the end.
    flat_timings: Vec<(Time, Time)>,

    result: SimResult,
    /// Bytes currently resident per memory point (indexed by `PointId`).
    mem_usage: Vec<u64>,
    /// Reused buffers (flow removal repair, successor fan-out, dead-path
    /// phantom cascade, completed-flow drain).
    flow_scratch: Vec<u32>,
    succ_scratch: Vec<TaskId>,
    dead_scratch: Vec<TaskId>,
    finished_scratch: Vec<Flow>,
}

impl<'a> Engine<'a> {
    fn new(
        hw: &'a Hardware,
        graph: &'a TaskGraph,
        mapping: &'a Mapping,
        evals: &'a Registry,
        cfg: &'a SimConfig,
        setup: &SimSetup,
        arena: Arena,
    ) -> Result<Self, SimError> {
        if cfg.iterations == 0 {
            return Err(SimError("iterations must be >= 1".into()));
        }
        // Validate placements of enabled tasks.
        for task in graph.iter().filter(|t| t.enabled) {
            match mapping.point_of(task.id) {
                None => {
                    return Err(SimError(format!(
                        "enabled task {} ({}) is unmapped",
                        task.id, task.name
                    )))
                }
                Some(p) => {
                    let kind = &hw.point(p).kind;
                    let ok = match &task.kind {
                        TaskKind::Compute(_) => kind.is_compute(),
                        TaskKind::Storage { .. } => kind.is_memory(),
                        TaskKind::Comm { .. } => kind.is_comm() || kind.is_memory(),
                        TaskKind::Sync { .. } => true,
                    };
                    if !ok {
                        return Err(SimError(format!(
                            "task {} ({}) of kind {} mapped to incompatible point {}",
                            task.id,
                            task.name,
                            task.kind.kind_name(),
                            hw.entry(p).addr
                        )));
                    }
                }
            }
        }
        // Pre-collect sync barriers.
        let mut syncs: HashMap<u32, SyncGroupState> = HashMap::new();
        for task in graph.iter().filter(|t| t.enabled) {
            if let TaskKind::Sync { sync_id } = task.kind {
                syncs
                    .entry(sync_id)
                    .or_insert_with(|| SyncGroupState {
                        members: Vec::new(),
                        progress: HashMap::new(),
                    })
                    .members
                    .push(task.id);
            }
        }
        let cap = graph.capacity();
        let slots = cap * cfg.iterations as usize;
        let mut point_of = arena.point_of;
        point_of.clear();
        point_of.resize(cap, None);
        for (t, p) in mapping.mapped_tasks() {
            if (t.index()) < point_of.len() {
                point_of[t.index()] = Some(p);
            }
        }
        // Intern every routed flow's link set once, remapped to dense
        // per-point indices, so the event loop never re-derives routes —
        // or adopt the setup's prebuilt table and skip even that.
        let routes = match &setup.routes {
            Some(rt) => Arc::clone(rt),
            None => Arc::new(RouteTable::build(hw, graph, &point_of)),
        };
        let n_points = hw.num_points();

        // Reset the arena in place: every buffer keeps its allocation when
        // it is already the right shape (same topology across candidates).
        let mut shared = arena.shared;
        if shared.len() != n_points {
            shared.clear();
            shared.resize_with(n_points, SharedPoint::default);
        }
        for (i, sp) in shared.iter_mut().enumerate() {
            sp.reset(routes.num_links(PointId(i as u32)));
        }
        let mut excl = arena.excl;
        if excl.len() != n_points {
            excl.clear();
            excl.resize_with(n_points, ExclPoint::default);
        }
        for ep in excl.iter_mut() {
            ep.reset();
        }
        let mut storage = arena.storage;
        storage.clear();
        storage.resize_with(cap, || None);
        let mut deps_left = arena.deps_left;
        deps_left.clear();
        deps_left.resize(slots, u32::MAX);
        let mut ready_time = arena.ready_time;
        ready_time.clear();
        ready_time.resize(slots, 0.0);
        let mut real_ticks = arena.real_ticks;
        real_ticks.clear();
        real_ticks.resize(slots, 0);
        let mut done_iters = arena.done_iters;
        done_iters.clear();
        done_iters.resize(cap, 0);
        let mut enabled_in_deg = arena.enabled_in_deg;
        graph.enabled_in_degrees_into(&mut enabled_in_deg);
        let mut demand_memo = arena.demand_memo;
        demand_memo.clear();
        demand_memo.resize_with(cap, || None);
        let mut demand_cache = arena.demand_cache;
        if setup.key.is_none() || arena.demand_token != setup.key {
            demand_cache.clear();
        }
        let mut flat_timings = arena.flat_timings;
        flat_timings.clear();
        flat_timings.resize(cap, (f64::NAN, f64::NAN));
        let mut mem_usage = arena.mem_usage;
        mem_usage.clear();
        mem_usage.resize(n_points, 0);
        let mut events = arena.events;
        events.clear();
        let mut event_payload = arena.event_payload;
        event_payload.clear();
        let mut flow_scratch = arena.flow_scratch;
        flow_scratch.clear();
        let mut succ_scratch = arena.succ_scratch;
        succ_scratch.clear();
        let mut dead_scratch = arena.dead_scratch;
        dead_scratch.clear();
        let mut finished_scratch = arena.finished_scratch;
        finished_scratch.clear();

        Ok(Engine {
            hw,
            graph,
            mapping,
            evals,
            cfg,
            events,
            event_payload,
            seq: 0,
            shared,
            excl,
            storage,
            syncs,
            routes,
            deps_left,
            ready_time,
            real_ticks,
            done_iters,
            point_of,
            enabled_in_deg,
            demand_memo,
            demand_cache,
            demand_token: setup.key,
            flat_timings,
            result: SimResult::default(),
            mem_usage,
            flow_scratch,
            succ_scratch,
            dead_scratch,
            finished_scratch,
        })
    }

    fn push_event(&mut self, time: Time, ev: Event) {
        let idx = self.event_payload.len() as u32;
        self.event_payload.push(ev);
        self.events.push(Reverse((OrdF64(time), self.seq, idx)));
        self.seq += 1;
    }

    /// (service demand, evaluation energy). With `cfg.dedup` the result is
    /// memoized twice: per task (repeat iterations hit a flat array) and
    /// per representative descriptor (the paper's §7.2 deduplication —
    /// evaluate one, reuse for identical tiles on the same point).
    fn demand_energy(&mut self, task: TaskId) -> (crate::eval::Demand, f64) {
        if let Some(de) = self.demand_memo[task.index()] {
            return de;
        }
        let t = self.graph.task(task);
        let p = self.point_of[task.index()].unwrap();
        if self.cfg.dedup {
            let key = match &t.kind {
                TaskKind::Compute(c) => {
                    let (op, dims, ib, ob, db, mf, vf) = c.dedup_key();
                    let h = (op as u64) << 32
                        ^ (dims[0] as u64) << 40
                        ^ (dims[1] as u64) << 20
                        ^ dims[2] as u64;
                    Some((h ^ mf.rotate_left(24) ^ vf.rotate_left(48), ib ^ ob.rotate_left(16), db, p.0))
                }
                TaskKind::Comm { bytes, hops, .. } => Some((*bytes, *hops, u64::MAX, p.0)),
                _ => None,
            };
            if let Some(key) = key {
                let de = if let Some(de) = self.demand_cache.get(&key) {
                    *de
                } else {
                    let ev = self.evals.for_point(self.hw.entry(p));
                    let de = (ev.demand(t, self.hw.entry(p)), ev.energy(t, self.hw.entry(p)));
                    self.demand_cache.insert(key, de);
                    de
                };
                self.demand_memo[task.index()] = Some(de);
                return de;
            }
        }
        let ev = self.evals.for_point(self.hw.entry(p));
        (ev.demand(t, self.hw.entry(p)), ev.energy(t, self.hw.entry(p)))
    }

    fn run(mut self, executor: &mut dyn Executor) -> Result<(SimResult, Arena), SimError> {
        // Inject source ticks.
        let sources: Vec<TaskId> = self
            .graph
            .iter()
            .filter(|t| t.enabled && self.graph.predecessors(t.id).iter().all(|p| {
                // predecessors that are disabled never fire; treat a task as a
                // source if all its preds are disabled
                !self.graph.task(*p).enabled
            }))
            .map(|t| t.id)
            .collect();
        for s in sources {
            for iter in 0..self.cfg.iterations {
                self.push_event(0.0, Event::Arrival(s, iter));
            }
        }

        // Wall-clock deadline state: checked on a coarse event stride so
        // the hot loop stays free of clock reads.
        const CLOCK_STRIDE: u64 = 4096;
        let started = (self.cfg.deadline_ms > 0).then(std::time::Instant::now);

        let mut processed = 0u64;
        while let Some(Reverse((OrdF64(now), _, idx))) = self.events.pop() {
            processed += 1;
            if processed > self.cfg.max_events {
                return Err(SimError(format!(
                    "event cap exceeded ({} events)",
                    self.cfg.max_events
                )));
            }
            if self.cfg.deadline_events > 0 && processed > self.cfg.deadline_events {
                return Err(SimError(format!(
                    "deadline exceeded: event budget ({} events)",
                    self.cfg.deadline_events
                )));
            }
            if let Some(t0) = started {
                if processed % CLOCK_STRIDE == 0
                    && t0.elapsed().as_millis() as u64 > self.cfg.deadline_ms
                {
                    return Err(SimError(format!(
                        "deadline exceeded: wall clock ({} ms)",
                        self.cfg.deadline_ms
                    )));
                }
            }
            match std::mem::replace(&mut self.event_payload[idx as usize], Event::ExclDone(PointId(u32::MAX), u64::MAX)) {
                Event::Arrival(task, iter) => self.on_arrival(task, iter, now, executor),
                Event::ExclDone(point, gen) => self.on_excl_done(point, gen, now, executor),
                Event::FlowDone(point, gen) => self.on_flow_done(point, gen, now, executor),
            }
        }

        // Wind down: release storage tasks without consumers at makespan.
        let makespan = self.result.makespan;
        for (i, slot_st) in self.storage.iter().enumerate() {
            let Some(st) = slot_st else { continue };
            if st.resident {
                let end = if st.consumers_left == 0 {
                    st.last_consumer_end
                } else {
                    makespan
                };
                let slot = &mut self.flat_timings[i];
                if slot.1.is_nan() || end > slot.1 {
                    *slot = (if slot.0.is_nan() { st.start } else { slot.0 }, end);
                }
            }
        }
        // fold flat timings into the public map
        for (i, (st, en)) in self.flat_timings.iter().enumerate() {
            if !en.is_nan() {
                self.result.timings.insert(TaskId(i as u32), (*st, *en));
            }
        }
        // Unfinished tasks.
        for t in self.graph.iter().filter(|t| t.enabled) {
            if t.kind.is_storage() {
                continue;
            }
            let done = self.done_iters[t.id.index()];
            if done < self.cfg.iterations {
                self.result.unfinished += 1;
            }
        }
        // Memory peaks vs capacity (index order: deterministic report).
        for (p, peak) in &self.result.peak_memory {
            if let Some(m) = self.hw.point(p).kind.as_memory() {
                if *peak > m.capacity {
                    self.result.memory_violations.push(format!(
                        "{}: peak {} bytes exceeds capacity {}",
                        self.hw.entry(p).addr,
                        peak,
                        m.capacity
                    ));
                }
            }
        }
        // Hand the arena back for the next run on this session.
        let result = std::mem::take(&mut self.result);
        let arena = Arena {
            events: self.events,
            event_payload: self.event_payload,
            shared: self.shared,
            excl: self.excl,
            storage: self.storage,
            deps_left: self.deps_left,
            ready_time: self.ready_time,
            real_ticks: self.real_ticks,
            done_iters: self.done_iters,
            point_of: self.point_of,
            enabled_in_deg: self.enabled_in_deg,
            demand_memo: self.demand_memo,
            demand_cache: self.demand_cache,
            flat_timings: self.flat_timings,
            mem_usage: self.mem_usage,
            flow_scratch: self.flow_scratch,
            succ_scratch: self.succ_scratch,
            dead_scratch: self.dead_scratch,
            finished_scratch: self.finished_scratch,
            demand_token: self.demand_token,
        };
        Ok((result, arena))
    }

    // ------------------------------------------------------------------
    // Event handlers
    // ------------------------------------------------------------------

    fn on_arrival(&mut self, task: TaskId, iter: u32, now: Time, executor: &mut dyn Executor) {
        // lightweight kind discriminant — avoids cloning route vectors
        enum K {
            Compute,
            Comm,
            Storage(u64),
            Sync(u32),
        }
        let kind = match &self.graph.task(task).kind {
            TaskKind::Compute(_) => K::Compute,
            TaskKind::Comm { .. } => K::Comm,
            TaskKind::Storage { bytes } => K::Storage(*bytes),
            TaskKind::Sync { sync_id } => K::Sync(*sync_id),
        };
        match kind {
            K::Compute => {
                let p = self.point_of[task.index()].unwrap();
                let excl = &mut self.excl[p.index()];
                excl.pending.push(Reverse((OrdF64(now), task, iter)));
                self.try_start_excl(p, now);
            }
            K::Comm => {
                let p = self.point_of[task.index()].unwrap();
                self.add_flow(p, task, iter, now);
            }
            K::Storage(bytes) => {
                // Eq. 2: activates at the first tick; output edges always
                // hold ticks — complete immediately at `now`.
                let consumers =
                    self.graph.successors(task).len() as u64 * self.cfg.iterations as u64;
                let p = self.point_of[task.index()].unwrap();
                let st = self.storage[task.index()].get_or_insert_with(|| StorageState {
                    resident: false,
                    bytes,
                    start: now,
                    consumers_left: consumers,
                    last_consumer_end: now,
                });
                if !st.resident {
                    st.resident = true;
                    st.start = now;
                    self.mem_usage[p.index()] += bytes;
                    let usage = self.mem_usage[p.index()];
                    let peak = self.result.peak_memory.entry_or(p, 0);
                    *peak = (*peak).max(usage);
                }
                self.complete(task, iter, now, now, executor);
            }
            K::Sync(sync_id) => {
                let members_done = {
                    let group = self.syncs.get_mut(&sync_id).expect("sync group");
                    let entry = group.progress.entry(iter).or_insert((0, 0.0));
                    entry.0 += 1;
                    entry.1 = entry.1.max(now);
                    entry.0 == group.members.len()
                };
                if members_done {
                    let group = &self.syncs[&sync_id];
                    let at = group.progress[&iter].1;
                    let members = group.members.clone();
                    for m in members {
                        self.complete(m, iter, at, at, executor);
                    }
                }
            }
        }
    }

    fn try_start_excl(&mut self, p: PointId, now: Time) {
        let excl = &mut self.excl[p.index()];
        if excl.running.is_some() {
            return;
        }
        let Some(Reverse((OrdF64(ready), task, iter))) = excl.pending.pop() else {
            return;
        };
        let start = ready.max(excl.timer).max(now);
        excl.generation += 1;
        let gen = excl.generation;
        let (demand, energy) = self.demand_energy(task);
        let end = start + demand.total();
        if energy > 0.0 {
            *self.result.point_energy.entry_or(p, 0.0) += energy;
        }
        let excl = &mut self.excl[p.index()];
        excl.running = Some((task, iter, start, end));
        *self.result.point_busy.entry_or(p, 0.0) += demand.total();
        if self.cfg.collect_timeline {
            self.result.timeline.push(TimelineEvent {
                task,
                iter,
                point: p,
                start,
                end,
            });
        }
        self.push_event(end, Event::ExclDone(p, gen));
    }

    fn on_excl_done(&mut self, p: PointId, gen: u64, now: Time, executor: &mut dyn Executor) {
        let excl = &mut self.excl[p.index()];
        if excl.generation != gen {
            return;
        }
        let (task, iter, start, end) = excl.running.take().expect("running task");
        excl.timer = end;
        self.complete(task, iter, start, end, executor);
        self.try_start_excl(p, now);
    }

    // ---------------- shared (fluid) resources ----------------

    fn add_flow(&mut self, p: PointId, task: TaskId, iter: u32, now: Time) {
        let (demand, energy) = self.demand_energy(task);
        if energy > 0.0 {
            *self.result.point_energy.entry_or(p, 0.0) += energy;
        }
        let links = self.routes.span_of(task);
        self.advance_flows(p, now);
        let total = demand.shared.max(0.0);
        let flow = Flow {
            task,
            iter,
            remaining: total,
            total,
            fixed: demand.fixed,
            links,
            bottleneck: 0,
            rate: 1.0,
            start: now,
        };
        self.shared[p.index()].add_flow_entry(flow, &self.routes, self.cfg.incremental);
        *self.result.point_busy.entry_or(p, 0.0) += demand.shared;
        self.reschedule_flows(p, now);
    }

    /// Integrate flow progress up to `now`.
    fn advance_flows(&mut self, p: PointId, now: Time) {
        let sp = &mut self.shared[p.index()];
        let dt = now - sp.last_update;
        if dt > 0.0 {
            for f in &mut sp.flows {
                f.remaining -= f.rate * dt;
                if f.remaining < 0.0 {
                    f.remaining = 0.0;
                }
            }
        }
        sp.last_update = now;
    }

    /// Re-derive rates (equal sharing of the bottleneck link) from the
    /// incrementally maintained occupancy and schedule the next completion
    /// candidate. congestion(f) = max occupancy over f's links + universal
    /// sharers; universal flows contend with everything. The expensive
    /// part — re-deriving bottlenecks — already happened in the ±1 delta
    /// updates; this pass is a flat O(flows) sweep. Without
    /// `cfg.incremental` the occupancy histogram and every bottleneck are
    /// rebuilt from scratch first (the pre-incremental engine, kept for
    /// golden cross-checks).
    fn reschedule_flows(&mut self, p: PointId, now: Time) {
        let (next, trunc) = {
            let routes = &self.routes;
            let sp = &mut self.shared[p.index()];
            if !self.cfg.incremental {
                for c in sp.occupancy.iter_mut() {
                    *c = 0;
                }
                sp.universal = 0;
                for f in &sp.flows {
                    let (off, len) = f.links;
                    if len == 0 {
                        sp.universal += 1;
                    } else {
                        for &l in routes.span(off, len) {
                            sp.occupancy[l as usize] += 1;
                        }
                    }
                }
                for f in &mut sp.flows {
                    let (off, len) = f.links;
                    if len > 0 {
                        let mut worst = 1u32;
                        for &l in routes.span(off, len) {
                            worst = worst.max(sp.occupancy[l as usize]);
                        }
                        f.bottleneck = worst;
                    }
                }
            }
            #[cfg(debug_assertions)]
            if self.cfg.incremental {
                sp.assert_consistent(routes);
            }
            let n = sp.flows.len() as u32;
            let universal = sp.universal;
            let mut trunc = 0u64;
            let mut earliest = f64::INFINITY;
            for f in &mut sp.flows {
                let congestion = if f.links.1 == 0 {
                    n
                } else {
                    f.bottleneck + universal
                };
                let r = 1.0 / congestion.max(1) as f64;
                if r < f.rate {
                    trunc += 1; // flow lost bandwidth: Algorithm-1 truncation
                }
                f.rate = r;
                let done = now + f.remaining / r;
                if done < earliest {
                    earliest = done;
                }
            }
            sp.generation += 1;
            let gen = sp.generation;
            (if n > 0 { Some((earliest, gen)) } else { None }, trunc)
        };
        self.result.truncations += trunc;
        if let Some((t, gen)) = next {
            self.push_event(t, Event::FlowDone(p, gen));
        }
    }

    fn on_flow_done(&mut self, p: PointId, gen: u64, now: Time, executor: &mut dyn Executor) {
        if self.shared[p.index()].generation != gen {
            return;
        }
        self.advance_flows(p, now);
        // complete all flows that hit zero (tolerance scaled to flow size)
        let mut finished = std::mem::take(&mut self.finished_scratch);
        finished.clear();
        {
            let incremental = self.cfg.incremental;
            let routes = &self.routes;
            let scratch = &mut self.flow_scratch;
            let sp = &mut self.shared[p.index()];
            let mut i = 0;
            while i < sp.flows.len() {
                if sp.flows[i].remaining <= completion_eps(sp.flows[i].total, now) {
                    finished.push(sp.remove_flow_entry(i, routes, incremental, scratch));
                } else {
                    i += 1;
                }
            }
        }
        for f in finished.drain(..) {
            let end = now + f.fixed;
            if self.cfg.collect_timeline {
                self.result.timeline.push(TimelineEvent {
                    task: f.task,
                    iter: f.iter,
                    point: p,
                    start: f.start,
                    end,
                });
            }
            self.complete(f.task, f.iter, f.start, end, executor);
        }
        self.finished_scratch = finished;
        if !self.shared[p.index()].flows.is_empty() {
            self.reschedule_flows(p, now);
        }
    }

    // ---------------- completion & tick propagation ----------------

    fn complete(
        &mut self,
        task: TaskId,
        iter: u32,
        start: Time,
        end: Time,
        executor: &mut dyn Executor,
    ) {
        self.result.completed += 1;
        if end > self.result.makespan {
            self.result.makespan = end;
        }
        self.flat_timings[task.index()] = (start, end);
        self.done_iters[task.index()] += 1;
        // Compute/comm timeline entries are recorded where they are issued;
        // storage and sync tasks are recorded here.
        let kind = &self.graph.task(task).kind;
        if self.cfg.collect_timeline && (kind.is_storage() || kind.is_sync()) {
            self.result.timeline.push(TimelineEvent {
                task,
                iter,
                point: self.mapping.point_of(task).unwrap_or(PointId(u32::MAX)),
                start,
                end,
            });
        }

        // Release storage predecessors.
        for &pred in self.graph.predecessors(task) {
            if let Some(st) = self.storage[pred.index()].as_mut() {
                if st.consumers_left > 0 {
                    st.consumers_left -= 1;
                    st.last_consumer_end = st.last_consumer_end.max(end);
                    if st.consumers_left == 0 && st.resident {
                        st.resident = false;
                        let p = self.point_of[pred.index()].unwrap();
                        self.mem_usage[p.index()] =
                            self.mem_usage[p.index()].saturating_sub(st.bytes);
                        self.flat_timings[pred.index()] = (st.start, st.last_consumer_end);
                    }
                }
            }
        }

        // Fire ticks on output edges (consulting the dynamic executor).
        // Untriggered successors receive *phantom* ticks: the dependency is
        // discharged without data, so a join after an untaken branch still
        // activates once its live inputs arrive, and all-phantom tasks die
        // and propagate phantoms downstream.
        let mut succs = std::mem::take(&mut self.succ_scratch);
        succs.clear();
        succs.extend_from_slice(self.graph.successors(task));
        let triggered = executor.triggered(task, &succs);
        for &s in &succs {
            let real = triggered.contains(&s);
            self.tick(s, iter, end, real);
        }
        self.succ_scratch = succs;
    }

    /// Deliver one tick (real or phantom) to `(task, iter)`, then discharge
    /// any dead-path cascade (all-phantom joins) iteratively — the reused
    /// stack pops in the same depth-first order the old recursion visited,
    /// without a `to_vec` allocation per dead task.
    fn tick(&mut self, s: TaskId, iter: u32, end: Time, real: bool) {
        self.tick_one(s, iter, end, real);
        while let Some(next) = self.dead_scratch.pop() {
            self.tick_one(next, iter, end, false);
        }
    }

    fn tick_one(&mut self, s: TaskId, iter: u32, end: Time, real: bool) {
        if !self.graph.task(s).enabled {
            return;
        }
        let iters = self.cfg.iterations as usize;
        let slot = s.index() * iters + iter as usize;
        if self.deps_left[slot] == u32::MAX {
            self.deps_left[slot] = self.enabled_in_deg[s.index()];
        }
        self.deps_left[slot] -= 1;
        if real {
            self.real_ticks[slot] += 1;
            if end > self.ready_time[slot] {
                self.ready_time[slot] = end;
            }
        }
        if self.deps_left[slot] == 0 {
            if self.real_ticks[slot] > 0 {
                let at = self.ready_time[slot];
                self.push_event(at, Event::Arrival(s, iter));
            } else {
                // dead path: queue successors for phantom discharge
                // (reversed so the stack pops them in graph order)
                for &next in self.graph.successors(s).iter().rev() {
                    self.dead_scratch.push(next);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::Registry;
    use crate::hwir::{
        CommAttrs, ComputeAttrs, Coord, Element, MemoryAttrs, SpaceMatrix, SpacePoint, Topology,
    };
    use crate::taskgraph::{ComputeCost, OpClass};

    /// One compute core + a bus comm point + a memory.
    fn tiny_hw(bus_bw: f64) -> Hardware {
        let mut m = SpaceMatrix::new("chip", vec![2]);
        m.set(
            Coord::new(vec![0]),
            Element::Point(SpacePoint::compute(
                "core",
                ComputeAttrs::new((4, 4), 8).with_lmem(MemoryAttrs::new(1 << 20, 64.0, 0)),
            )),
        );
        m.set(
            Coord::new(vec![1]),
            Element::Point(SpacePoint::memory("mem", MemoryAttrs::new(4096, 16.0, 0))),
        );
        m.add_comm(SpacePoint::comm(
            "bus",
            CommAttrs::new(Topology::Bus, bus_bw, 0),
        ));
        Hardware::build(m)
    }

    fn compute_task(cycles: f64) -> TaskKind {
        // vec_flops chosen so demand = cycles on 8 lanes (2*8 flops/cycle)
        let mut c = ComputeCost::zero(OpClass::Elementwise);
        c.vec_flops = cycles * 16.0;
        TaskKind::Compute(c)
    }

    fn comm_task(bytes: u64) -> TaskKind {
        TaskKind::Comm { bytes, hops: 0, route: None }
    }

    #[test]
    fn single_chain_timing() {
        let hw = tiny_hw(1.0);
        let mut g = TaskGraph::new();
        let a = g.add("a", compute_task(100.0));
        let b = g.add("b", comm_task(50)); // 50 bytes / 1 B/cyc = 50 cycles
        let c = g.add("c", compute_task(25.0));
        g.connect(a, b);
        g.connect(b, c);
        let core = hw.points_of_kind("compute")[0];
        let bus = hw.points_of_kind("comm")[0];
        let mut m = Mapping::new();
        m.map(a, core);
        m.map(b, bus);
        m.map(c, core);
        let r = simulate(&hw, &g, &m, &Registry::standard(), &SimConfig::default()).unwrap();
        assert_eq!(r.makespan, 175.0);
        assert_eq!(r.timings[&a].1, 100.0);
        assert_eq!(r.timings[&b].1, 150.0);
        assert_eq!(r.timings[&c], (150.0, 175.0));
        assert_eq!(r.completed, 3);
        assert_eq!(r.unfinished, 0);
    }

    #[test]
    fn exclusive_point_serializes() {
        let hw = tiny_hw(1.0);
        let mut g = TaskGraph::new();
        let a = g.add("a", compute_task(100.0));
        let b = g.add("b", compute_task(100.0));
        let core = hw.points_of_kind("compute")[0];
        let mut m = Mapping::new();
        m.map(a, core);
        m.map(b, core);
        let r = simulate(&hw, &g, &m, &Registry::standard(), &SimConfig::default()).unwrap();
        // both ready at 0; serialized on the exclusive core
        assert_eq!(r.makespan, 200.0);
        assert!((r.utilization(core) - 1.0).abs() < 1e-9);
    }

    /// Hardware-consistent contention (paper Fig. 6 scenario, our numbers):
    /// E (compute, 100 cy) fires A (50 work) and F (200 work) on a shared
    /// bus; A's successor B (compute, 100 cy) fires C (80 work) on the bus.
    ///
    /// Fluid timeline: A,F share from 100; A done at 200 (rate ½).
    /// F alone until C arrives at 300 with 100 work left -> 50 left at 300;
    /// F,C share: F done at 400; C has 50 done, 30 left alone -> done 430.
    #[test]
    fn fig6_hardware_consistent_contention() {
        let hw = tiny_hw(1.0);
        let mut g = TaskGraph::new();
        let e = g.add("E", compute_task(100.0));
        let a = g.add("A", comm_task(50));
        let f = g.add("F", comm_task(200));
        let b = g.add("B", compute_task(100.0));
        let c = g.add("C", comm_task(80));
        g.connect(e, a);
        g.connect(e, f);
        g.connect(a, b);
        g.connect(b, c);
        let core = hw.points_of_kind("compute")[0];
        let bus = hw.points_of_kind("comm")[0];
        let mut m = Mapping::new();
        m.map(e, core);
        m.map(b, core);
        for t in [a, f, c] {
            m.map(t, bus);
        }
        let r = simulate(&hw, &g, &m, &Registry::standard(), &SimConfig::default()).unwrap();
        assert_eq!(r.timings[&e].1, 100.0);
        assert_eq!(r.timings[&a].1, 200.0, "A shares the bus with F");
        assert_eq!(r.timings[&b].1, 300.0);
        assert_eq!(r.timings[&f].1, 400.0, "F truncated by C's arrival");
        assert_eq!(r.timings[&c].1, 430.0);
        assert!(r.truncations >= 2, "A/F then F/C sharing");
    }

    #[test]
    fn link_level_contention_on_mesh() {
        // 1x3 mesh; flows (0)->(2) and (0)->(1) share the first link;
        // flow (1)->(2) moves opposite... no — (1)->(2) shares link 1 with
        // (0)->(2). Verify halved bandwidth on the shared prefix.
        let mut m = SpaceMatrix::new("chip", vec![3]);
        for i in 0..3 {
            m.set(
                Coord::new(vec![i]),
                Element::Point(SpacePoint::compute(
                    "core",
                    ComputeAttrs::new((4, 4), 8).with_lmem(MemoryAttrs::new(1 << 20, 64.0, 0)),
                )),
            );
        }
        m.add_comm(SpacePoint::comm(
            "noc",
            CommAttrs::new(Topology::Mesh, 1.0, 0),
        ));
        let hw = Hardware::build(m);
        let noc = hw.points_of_kind("comm")[0];

        let mut g = TaskGraph::new();
        let mk = |g: &mut TaskGraph, name: &str, bytes: u64, from: u32, to: u32| {
            g.add(
                name,
                TaskKind::Comm {
                    bytes,
                    hops: (from as i64 - to as i64).unsigned_abs(),
                    route: Some((Coord::new(vec![from]), Coord::new(vec![to]))),
                },
            )
        };
        let x = mk(&mut g, "x", 100, 0, 2); // links 0,1
        let y = mk(&mut g, "y", 100, 0, 1); // link 0 (shared with x)
        let z = mk(&mut g, "z", 100, 2, 0); // reverse direction: no contention
        let mut map = Mapping::new();
        for t in [x, y, z] {
            map.map(t, noc);
        }
        let r = simulate(&hw, &g, &map, &Registry::standard(), &SimConfig::default()).unwrap();
        // z runs at full rate: 100 cycles. x,y share link 0: both at rate ½
        // until y (100 work) is done at 200; x finishes its last 0 work...
        // both x and y have 100 work; equal rates -> both complete at 200.
        assert_eq!(r.timings[&z].1, 100.0);
        assert_eq!(r.timings[&y].1, 200.0);
        assert_eq!(r.timings[&x].1, 200.0);
    }

    #[test]
    fn storage_lifecycle_and_peak_memory() {
        let hw = tiny_hw(1.0);
        let mut g = TaskGraph::new();
        let w = g.add("weights", TaskKind::Storage { bytes: 3000 });
        let a = g.add("a", compute_task(50.0));
        let c = g.add("use", compute_task(10.0));
        g.connect(w, c);
        g.connect(a, c);
        let core = hw.points_of_kind("compute")[0];
        let mem = hw.points_of_kind("memory")[0];
        let mut m = Mapping::new();
        m.map(w, mem);
        m.map(a, core);
        m.map(c, core);
        let r = simulate(&hw, &g, &m, &Registry::standard(), &SimConfig::default()).unwrap();
        assert_eq!(r.peak_memory[&mem], 3000);
        assert!(r.memory_violations.is_empty());
        // storage lives until its consumer finishes at 60
        assert_eq!(r.timings[&w], (0.0, 60.0));
    }

    #[test]
    fn memory_capacity_violation_reported() {
        let hw = tiny_hw(1.0);
        let mut g = TaskGraph::new();
        let w = g.add("big", TaskKind::Storage { bytes: 10_000 }); // mem cap 4096
        let c = g.add("c", compute_task(1.0));
        g.connect(w, c);
        let mut m = Mapping::new();
        m.map(w, hw.points_of_kind("memory")[0]);
        m.map(c, hw.points_of_kind("compute")[0]);
        let r = simulate(&hw, &g, &m, &Registry::standard(), &SimConfig::default()).unwrap();
        assert_eq!(r.memory_violations.len(), 1);
    }

    #[test]
    fn sync_barrier_completes_at_max_ready() {
        let hw = tiny_hw(1.0);
        let core = hw.points_of_kind("compute")[0];
        let bus = hw.points_of_kind("comm")[0];
        let mut g = TaskGraph::new();
        let a = g.add("a", compute_task(100.0));
        let b = g.add("b", comm_task(30)); // done at 30 on bus
        let s1 = g.add("s1", TaskKind::Sync { sync_id: 9 });
        let s2 = g.add("s2", TaskKind::Sync { sync_id: 9 });
        let after = g.add("after", compute_task(10.0));
        g.connect(a, s1);
        g.connect(b, s2);
        g.connect(s1, after);
        g.connect(s2, after);
        let mut m = Mapping::new();
        m.map(a, core);
        m.map(b, bus);
        m.map(s1, core);
        m.map(s2, bus);
        m.map(after, core);
        let r = simulate(&hw, &g, &m, &Registry::standard(), &SimConfig::default()).unwrap();
        // barrier at max(100, 30) = 100; after runs 100..110
        assert_eq!(r.timings[&s1].1, 100.0);
        assert_eq!(r.timings[&s2].1, 100.0);
        assert_eq!(r.timings[&after], (100.0, 110.0));
    }

    #[test]
    fn iterations_stream_through() {
        let hw = tiny_hw(1.0);
        let core = hw.points_of_kind("compute")[0];
        let mut g = TaskGraph::new();
        let a = g.add("a", compute_task(10.0));
        let mut m = Mapping::new();
        m.map(a, core);
        let cfg = SimConfig {
            iterations: 5,
            ..Default::default()
        };
        let r = simulate(&hw, &g, &m, &Registry::standard(), &cfg).unwrap();
        assert_eq!(r.completed, 5);
        assert_eq!(r.makespan, 50.0); // serialized on the core
    }

    #[test]
    fn disabled_tasks_are_skipped() {
        let hw = tiny_hw(1.0);
        let core = hw.points_of_kind("compute")[0];
        let mut g = TaskGraph::new();
        let a = g.add("a", compute_task(10.0));
        let b = g.add("b", compute_task(10.0));
        g.task_mut(b).enabled = false;
        g.connect(a, b);
        let mut m = Mapping::new();
        m.map(a, core);
        let r = simulate(&hw, &g, &m, &Registry::standard(), &SimConfig::default()).unwrap();
        assert_eq!(r.completed, 1);
        assert_eq!(r.makespan, 10.0);
    }

    #[test]
    fn unmapped_enabled_task_is_an_error() {
        let hw = tiny_hw(1.0);
        let mut g = TaskGraph::new();
        g.add("a", compute_task(10.0));
        let m = Mapping::new();
        assert!(simulate(&hw, &g, &m, &Registry::standard(), &SimConfig::default()).is_err());
    }

    #[test]
    fn dynamic_executor_prunes_branch() {
        let hw = tiny_hw(1.0);
        let core = hw.points_of_kind("compute")[0];
        let mut g = TaskGraph::new();
        let a = g.add("a", compute_task(10.0));
        let b = g.add("b", compute_task(10.0));
        let c = g.add("c", compute_task(1000.0));
        g.connect(a, b);
        g.connect(a, c);
        let mut m = Mapping::new();
        for t in [a, b, c] {
            m.map(t, core);
        }
        let mut trace = crate::taskgraph::Trace::new([a, b]);
        let r = simulate_dynamic(
            &hw,
            &g,
            &m,
            &Registry::standard(),
            &SimConfig::default(),
            &mut trace,
        )
        .unwrap();
        assert_eq!(r.makespan, 20.0); // c never triggered
        assert_eq!(r.unfinished, 1);
    }

    #[test]
    fn huge_transfers_complete_despite_float_residue() {
        // Bytes near 2^50: with the old absolute 1e-9 completion epsilon,
        // the float residue left in `remaining` after advancing could
        // exceed the tolerance — and the rescheduled completion could
        // round below the time resolution, respawning zero-length
        // FlowDone events until the event cap. The size-scaled tolerance
        // must finish in a handful of events with exact work conservation.
        let hw = tiny_hw(1.0);
        let bus = hw.points_of_kind("comm")[0];
        let mut g = TaskGraph::new();
        let work = [(1u64 << 50) + 1, (1u64 << 50) + 3, (1u64 << 50) + 7];
        let mut m = Mapping::new();
        for (i, w) in work.iter().enumerate() {
            let t = g.add(format!("x{i}"), comm_task(*w));
            m.map(t, bus);
        }
        let cfg = SimConfig {
            max_events: 10_000,
            ..Default::default()
        };
        let r = simulate(&hw, &g, &m, &Registry::standard(), &cfg).unwrap();
        assert_eq!(r.completed, 3);
        // unit-bandwidth shared bus that is never idle: makespan == total
        let total: f64 = work.iter().map(|w| *w as f64).sum();
        assert!(
            (r.makespan - total).abs() / total < 1e-9,
            "{} vs {total}",
            r.makespan
        );
    }

    #[test]
    fn small_flows_late_in_long_simulations_complete() {
        // A ~2^50-cycle transfer shares the bus with a 100-byte flow
        // released near its end: the small flow's residue after advancing
        // (~ulp of the absolute time) dwarfs any size-scaled tolerance,
        // so the epsilon must scale with simulation time too, or the
        // completion event respawns at the same timestamp forever.
        let hw = tiny_hw(1.0);
        let core = hw.points_of_kind("compute")[0];
        let bus = hw.points_of_kind("comm")[0];
        let mut g = TaskGraph::new();
        let big = g.add("big", comm_task(1u64 << 50));
        let gate = g.add("gate", compute_task((1u64 << 50) as f64 - 1000.0));
        let small = g.add("small", comm_task(100));
        g.connect(gate, small);
        let mut m = Mapping::new();
        m.map(big, bus);
        m.map(gate, core);
        m.map(small, bus);
        let cfg = SimConfig {
            max_events: 10_000,
            ..Default::default()
        };
        let r = simulate(&hw, &g, &m, &Registry::standard(), &cfg).unwrap();
        assert_eq!(r.completed, 3);
        assert!(r.makespan >= (1u64 << 50) as f64);
    }

    #[test]
    fn event_deadline_fails_runaway_candidates_deterministically() {
        // Ten serial compute tasks need well over 3 events; the deadline
        // error must say so (the DSE engine surfaces that exact phrase as
        // the candidate's failure), and a roomy budget must not perturb
        // the result at all.
        let hw = tiny_hw(1.0);
        let core = hw.points_of_kind("compute")[0];
        let mut g = TaskGraph::new();
        let mut m = Mapping::new();
        let mut prev = None;
        for i in 0..10 {
            let t = g.add(format!("t{i}"), compute_task(10.0));
            m.map(t, core);
            if let Some(p) = prev {
                g.connect(p, t);
            }
            prev = Some(t);
        }
        let tight = SimConfig {
            deadline_events: 3,
            ..Default::default()
        };
        let err = simulate(&hw, &g, &m, &Registry::standard(), &tight).unwrap_err();
        assert!(err.to_string().contains("deadline exceeded"), "{err}");
        assert!(err.to_string().contains("3 events"), "{err}");

        let roomy = SimConfig {
            deadline_events: 1_000_000,
            deadline_ms: 600_000,
            ..Default::default()
        };
        let bounded = simulate(&hw, &g, &m, &Registry::standard(), &roomy).unwrap();
        let free = simulate(&hw, &g, &m, &Registry::standard(), &SimConfig::default()).unwrap();
        assert_eq!(bounded, free, "a roomy deadline must not change results");
    }

    #[test]
    fn full_recompute_path_matches_incremental() {
        // fig6 scenario under both contention paths: bit-identical output
        let hw = tiny_hw(1.0);
        let mut g = TaskGraph::new();
        let e = g.add("E", compute_task(100.0));
        let a = g.add("A", comm_task(50));
        let f = g.add("F", comm_task(200));
        let b = g.add("B", compute_task(100.0));
        let c = g.add("C", comm_task(80));
        g.connect(e, a);
        g.connect(e, f);
        g.connect(a, b);
        g.connect(b, c);
        let core = hw.points_of_kind("compute")[0];
        let bus = hw.points_of_kind("comm")[0];
        let mut m = Mapping::new();
        m.map(e, core);
        m.map(b, core);
        for t in [a, f, c] {
            m.map(t, bus);
        }
        let base = SimConfig {
            collect_timeline: true,
            ..Default::default()
        };
        let incr = simulate(&hw, &g, &m, &Registry::standard(), &base).unwrap();
        let full_cfg = SimConfig {
            incremental: false,
            ..base
        };
        let full = simulate(&hw, &g, &m, &Registry::standard(), &full_cfg).unwrap();
        assert_eq!(incr, full);
    }

    /// Session re-entry: back-to-back runs on one `SimSession` (same and
    /// different workloads, with and without a prebuilt route table and a
    /// shared setup key) are bit-identical to the stateless entry point.
    #[test]
    fn sim_session_reuse_is_bit_identical() {
        let hw = tiny_hw(1.0);
        let mut g = TaskGraph::new();
        let e = g.add("E", compute_task(100.0));
        let a = g.add("A", comm_task(50));
        let f = g.add("F", comm_task(200));
        let b = g.add("B", compute_task(100.0));
        let c = g.add("C", comm_task(80));
        g.connect(e, a);
        g.connect(e, f);
        g.connect(a, b);
        g.connect(b, c);
        let core = hw.points_of_kind("compute")[0];
        let bus = hw.points_of_kind("comm")[0];
        let mut m = Mapping::new();
        m.map(e, core);
        m.map(b, core);
        for t in [a, f, c] {
            m.map(t, bus);
        }
        let cfg = SimConfig {
            collect_timeline: true,
            ..Default::default()
        };
        let golden = simulate(&hw, &g, &m, &Registry::standard(), &cfg).unwrap();

        let evals = Registry::standard();
        let mut session = SimSession::new();
        // plain session reuse: arenas reset in place between runs
        for _ in 0..3 {
            let r = session.simulate(&hw, &g, &m, &evals, &cfg).unwrap();
            assert_eq!(r, golden);
        }
        // prepared setup: prebuilt route table + stable key (warm demand
        // cache across runs)
        let mut point_of = vec![None; g.capacity()];
        for (t, p) in m.mapped_tasks() {
            point_of[t.index()] = Some(p);
        }
        let routes = Arc::new(RouteTable::build(&hw, &g, &point_of));
        let setup = SimSetup {
            routes: Some(routes),
            key: Some(42),
        };
        for _ in 0..3 {
            let r = session
                .simulate_prepared(&hw, &g, &m, &evals, &cfg, &setup)
                .unwrap();
            assert_eq!(r, golden);
        }
        // interleave a different-shaped workload: arenas must re-shape
        let hw2 = tiny_hw(2.0);
        let mut g2 = TaskGraph::new();
        let x = g2.add("x", compute_task(10.0));
        let mut m2 = Mapping::new();
        m2.map(x, hw2.points_of_kind("compute")[0]);
        let small = session.simulate(&hw2, &g2, &m2, &evals, &cfg).unwrap();
        assert_eq!(small.makespan, 10.0);
        let r = session.simulate(&hw, &g, &m, &evals, &cfg).unwrap();
        assert_eq!(r, golden);
    }

    #[test]
    fn prop_makespan_at_least_critical_path() {
        use crate::util::propcheck::{check, Gen};
        check("makespan >= critical path lower bound", 24, |gen: &mut Gen| {
            let hw = tiny_hw(1.0);
            let core = hw.points_of_kind("compute")[0];
            let n = gen.usize(1..=12);
            let mut g = TaskGraph::new();
            let mut cycles = Vec::new();
            let ids: Vec<TaskId> = (0..n)
                .map(|i| {
                    let c = gen.usize(1..=50) as f64;
                    cycles.push(c);
                    g.add(format!("t{i}"), compute_task(c))
                })
                .collect();
            for i in 0..n {
                for j in i + 1..n {
                    if gen.bool() && gen.bool() {
                        g.connect(ids[i], ids[j]);
                    }
                }
            }
            let mut m = Mapping::new();
            for id in &ids {
                m.map(*id, core);
            }
            let r = simulate(&hw, &g, &m, &Registry::standard(), &SimConfig::default())
                .map_err(|e| e.to_string())?;
            // all on one exclusive core: makespan == sum of cycles
            let sum: f64 = cycles.iter().sum();
            if (r.makespan - sum).abs() > 1e-6 {
                return Err(format!("makespan {} != serial sum {}", r.makespan, sum));
            }
            Ok(())
        });
    }
}
