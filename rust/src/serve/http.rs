//! Minimal HTTP/1.1 plumbing for the serve daemon.
//!
//! The crate is zero-dependency, so this is a hand-rolled subset of the
//! protocol — exactly what the job API needs and nothing more: one
//! request per connection (`Connection: close`), `Content-Length` bodies
//! on the way in, and either fixed-length JSON or chunked NDJSON on the
//! way out. Parsing is strict about the request line and tolerant about
//! headers it does not understand.

use std::io::{BufRead, Read, Write};

use crate::util::json::{Json, JsonObj};

/// Largest request body the daemon will read (space documents are small;
/// anything bigger is a client error, not a reason to balloon memory).
pub const MAX_BODY_BYTES: usize = 16 * 1024 * 1024;

/// One parsed request: method, raw path (query string included) and the
/// decoded UTF-8 body (empty when the request had none).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub body: String,
}

/// Why a request could not be read, carrying the HTTP status the daemon
/// answers with: 408 for socket timeouts (slow-loris clients, stalled
/// uploads), 413 for oversized bodies, 400 for everything else.
#[derive(Debug)]
pub enum ParseError {
    /// Declared `Content-Length` above [`MAX_BODY_BYTES`].
    TooLarge { declared: usize },
    /// The socket read timed out before a complete request arrived.
    Timeout,
    /// Malformed bytes: bad request line, bad header, invalid UTF-8,
    /// or the connection dropped mid-request.
    Malformed(String),
}

impl ParseError {
    /// The status code this error answers with.
    pub fn status(&self) -> u16 {
        match self {
            ParseError::TooLarge { .. } => 413,
            ParseError::Timeout => 408,
            ParseError::Malformed(_) => 400,
        }
    }

    /// Diagnostic JSON for the error response. Oversized requests name
    /// both the declared size and the limit so clients can fix
    /// themselves without reading server code.
    pub fn to_json(&self) -> Json {
        let mut o = JsonObj::new();
        o.insert("error", self.to_string().as_str().into());
        if let ParseError::TooLarge { declared } = self {
            o.insert("declared_bytes", (*declared).into());
            o.insert("limit_bytes", MAX_BODY_BYTES.into());
        }
        Json::Obj(o)
    }
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ParseError::TooLarge { declared } => write!(
                f,
                "http: request body too large ({declared} bytes, limit {MAX_BODY_BYTES})"
            ),
            ParseError::Timeout => {
                write!(f, "http: timed out reading the request (slow client)")
            }
            ParseError::Malformed(msg) => write!(f, "{msg}"),
        }
    }
}

/// Classify an I/O failure mid-parse: an expired socket read timeout is
/// the client's fault (408), anything else is a malformed/broken request.
fn read_err(e: std::io::Error) -> ParseError {
    match e.kind() {
        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut => ParseError::Timeout,
        _ => ParseError::Malformed(format!("http: reading request: {e}")),
    }
}

/// Read one request from `r`. Headers other than `Content-Length` are
/// skipped; the body is read to exactly the declared length, which is
/// capped at [`MAX_BODY_BYTES`] **before** any allocation happens.
pub fn parse_request<R: BufRead>(r: &mut R) -> std::result::Result<Request, ParseError> {
    let mut start = String::new();
    let n = r.read_line(&mut start).map_err(read_err)?;
    if n == 0 {
        return Err(ParseError::Malformed(
            "http: connection closed before a request line".to_string(),
        ));
    }
    let mut parts = start.split_whitespace();
    let method = parts.next().unwrap_or_default().to_string();
    let path = parts.next().unwrap_or_default().to_string();
    if method.is_empty() || !path.starts_with('/') {
        return Err(ParseError::Malformed(format!(
            "http: malformed request line '{}'",
            start.trim_end()
        )));
    }
    let mut content_len = 0usize;
    loop {
        let mut line = String::new();
        if r.read_line(&mut line).map_err(read_err)? == 0 {
            break;
        }
        let line = line.trim_end();
        if line.is_empty() {
            break;
        }
        if let Some((key, value)) = line.split_once(':') {
            if key.trim().eq_ignore_ascii_case("content-length") {
                let value = value.trim();
                content_len = value.parse().map_err(|_| {
                    ParseError::Malformed(format!("http: invalid Content-Length '{value}'"))
                })?;
            }
        }
    }
    if content_len > MAX_BODY_BYTES {
        return Err(ParseError::TooLarge {
            declared: content_len,
        });
    }
    let mut body = vec![0u8; content_len];
    r.read_exact(&mut body).map_err(read_err)?;
    let body = String::from_utf8(body).map_err(|_| {
        ParseError::Malformed("http: request body is not valid UTF-8".to_string())
    })?;
    Ok(Request { method, path, body })
}

/// Canonical reason phrase for the status codes the daemon emits.
pub fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        201 => "Created",
        202 => "Accepted",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        409 => "Conflict",
        413 => "Payload Too Large",
        422 => "Unprocessable Entity",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

/// Write a complete fixed-length response and flush it.
pub fn write_response(
    w: &mut impl Write,
    status: u16,
    content_type: &str,
    body: &str,
) -> std::io::Result<()> {
    write!(
        w,
        "HTTP/1.1 {status} {}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        reason(status),
        body.len()
    )?;
    w.write_all(body.as_bytes())?;
    w.flush()
}

/// Write a JSON response (pretty-printed, newline-terminated).
pub fn write_json(
    w: &mut impl Write,
    status: u16,
    doc: &crate::util::json::Json,
) -> std::io::Result<()> {
    let body = format!("{}\n", doc.to_pretty());
    write_response(w, status, "application/json", &body)
}

/// Start a chunked 200 response; follow with [`write_chunk`] and close
/// with [`finish_chunked`].
pub fn start_chunked(w: &mut impl Write, content_type: &str) -> std::io::Result<()> {
    write!(
        w,
        "HTTP/1.1 200 OK\r\nContent-Type: {content_type}\r\nTransfer-Encoding: chunked\r\nConnection: close\r\n\r\n"
    )?;
    w.flush()
}

/// Write one chunk. Empty data is skipped — a zero-length chunk would
/// terminate the stream.
pub fn write_chunk(w: &mut impl Write, data: &str) -> std::io::Result<()> {
    if data.is_empty() {
        return Ok(());
    }
    write!(w, "{:x}\r\n", data.len())?;
    w.write_all(data.as_bytes())?;
    w.write_all(b"\r\n")?;
    w.flush()
}

/// Terminate a chunked response.
pub fn finish_chunked(w: &mut impl Write) -> std::io::Result<()> {
    w.write_all(b"0\r\n\r\n")?;
    w.flush()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn parses_request_with_body() {
        let raw = "POST /jobs HTTP/1.1\r\nHost: x\r\nContent-Length: 4\r\n\r\nabcd";
        let req = parse_request(&mut Cursor::new(raw.as_bytes())).unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/jobs");
        assert_eq!(req.body, "abcd");
    }

    #[test]
    fn parses_bodyless_request_and_case_insensitive_header() {
        let raw = "GET /jobs/1 HTTP/1.1\r\ncontent-LENGTH: 0\r\n\r\n";
        let req = parse_request(&mut Cursor::new(raw.as_bytes())).unwrap();
        assert_eq!(req.method, "GET");
        assert_eq!(req.path, "/jobs/1");
        assert!(req.body.is_empty());
    }

    #[test]
    fn rejects_garbage_request_line() {
        let err = parse_request(&mut Cursor::new(b"nonsense\r\n\r\n".as_slice()))
            .unwrap_err()
            .to_string();
        assert!(err.contains("malformed request line"), "{err}");
    }

    #[test]
    fn rejects_invalid_content_length() {
        let raw = "POST /jobs HTTP/1.1\r\nContent-Length: lots\r\n\r\n";
        let err = parse_request(&mut Cursor::new(raw.as_bytes()))
            .unwrap_err()
            .to_string();
        assert!(err.contains("invalid Content-Length 'lots'"), "{err}");
    }

    #[test]
    fn oversized_content_length_is_413_with_diagnostics() {
        let declared = MAX_BODY_BYTES + 1;
        let raw = format!("POST /jobs HTTP/1.1\r\nContent-Length: {declared}\r\n\r\n");
        let err = parse_request(&mut Cursor::new(raw.as_bytes())).unwrap_err();
        assert_eq!(err.status(), 413);
        let doc = err.to_json();
        assert_eq!(doc.get("declared_bytes").and_then(|v| v.as_usize()), Some(declared));
        assert_eq!(
            doc.get("limit_bytes").and_then(|v| v.as_usize()),
            Some(MAX_BODY_BYTES)
        );
        let msg = err.to_string();
        assert!(msg.contains("request body too large"), "{msg}");
    }

    /// A reader that never produces data, like a socket whose read
    /// timeout expired mid-request.
    struct Stalled;

    impl Read for Stalled {
        fn read(&mut self, _buf: &mut [u8]) -> std::io::Result<usize> {
            Err(std::io::Error::new(
                std::io::ErrorKind::WouldBlock,
                "stalled",
            ))
        }
    }

    #[test]
    fn stalled_client_is_a_408_timeout() {
        let err = parse_request(&mut std::io::BufReader::new(Stalled)).unwrap_err();
        assert_eq!(err.status(), 408);
        assert!(err.to_string().contains("timed out"), "{err}");
    }

    #[test]
    fn truncated_body_is_a_400_not_a_timeout() {
        // Content-Length promises more bytes than the client sends.
        let raw = "POST /jobs HTTP/1.1\r\nContent-Length: 10\r\n\r\nabc";
        let err = parse_request(&mut Cursor::new(raw.as_bytes())).unwrap_err();
        assert_eq!(err.status(), 400);
    }

    #[test]
    fn reason_phrases_cover_the_hardening_statuses() {
        assert_eq!(reason(408), "Request Timeout");
        assert_eq!(reason(413), "Payload Too Large");
        assert_eq!(reason(503), "Service Unavailable");
    }

    #[test]
    fn chunked_framing_round_trips() {
        let mut out: Vec<u8> = Vec::new();
        start_chunked(&mut out, "application/x-ndjson").unwrap();
        write_chunk(&mut out, "hello\n").unwrap();
        write_chunk(&mut out, "").unwrap(); // skipped, not a terminator
        write_chunk(&mut out, "world\n").unwrap();
        finish_chunked(&mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"), "{text}");
        assert!(text.contains("Transfer-Encoding: chunked"), "{text}");
        assert!(text.contains("6\r\nhello\n\r\n"), "{text}");
        assert!(text.ends_with("0\r\n\r\n"), "{text}");
    }

    #[test]
    fn fixed_response_has_content_length() {
        let mut out: Vec<u8> = Vec::new();
        write_response(&mut out, 404, "application/json", "{}\n").unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 404 Not Found\r\n"), "{text}");
        assert!(text.contains("Content-Length: 3\r\n"), "{text}");
        assert!(text.ends_with("{}\n"), "{text}");
    }
}
