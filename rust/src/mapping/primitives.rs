//! Mapping action primitives (paper §5.2, Table 1).
//!
//! The sixteen primitives operate on a [`MappingState`] — the pair of task
//! graph + mapping that a mapping-search algorithm evolves. Every mutating
//! primitive checkpoints the state first, so the *state control* primitives
//! `undo` / `redo` can step the search backwards and forwards (the paper's
//! substrate for e.g. Monte-Carlo tree search).
//!
//! | type | primitives |
//! |---|---|
//! | graph transformation | `group`, `tile_task`, `tile_group`, `split_edge`, `delete_task`, `copy_task`, `connect` |
//! | task assignment | `map_node`, `take_out`, `map_edge`, `take_edge_out` |
//! | synchronization | `sync` (+ `barrier` helper) |
//! | state control | `enable`, `disable`, `undo`, `redo` |

use std::collections::VecDeque;

use crate::hwir::{CommSegment, PointId};
use crate::taskgraph::{TaskGraph, TaskId, TaskKind};

use super::ir::Mapping;

/// Error type of primitive application.
#[derive(Debug, Clone, PartialEq)]
pub struct MapError(pub String);

impl std::fmt::Display for MapError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "mapping error: {}", self.0)
    }
}

/// Primitive failures propagate into the crate-wide error chain with
/// their detail preserved as a separate context level, so callers can
/// stack higher-level context on top (`?` + `Context::context`) instead
/// of re-formatting ad-hoc strings.
impl From<MapError> for crate::util::error::Error {
    fn from(e: MapError) -> crate::util::error::Error {
        crate::util::error::Error::msg(e.0).wrap("mapping error")
    }
}

type Result<T> = std::result::Result<T, MapError>;

fn err<T>(msg: impl Into<String>) -> Result<T> {
    Err(MapError(msg.into()))
}

#[derive(Debug, Clone)]
struct Snapshot {
    graph: TaskGraph,
    mapping: Mapping,
    next_group: u32,
}

/// Task graph + mapping under search, with undo/redo history.
#[derive(Debug)]
pub struct MappingState {
    pub graph: TaskGraph,
    pub mapping: Mapping,
    next_group: u32,
    /// Checkpoint ring: old entries evict from the front in O(1).
    undo_stack: VecDeque<Snapshot>,
    redo_stack: Vec<Snapshot>,
    /// Maximum retained checkpoints (old ones are dropped).
    pub history_limit: usize,
}

impl MappingState {
    pub fn new(graph: TaskGraph) -> Self {
        MappingState {
            graph,
            mapping: Mapping::new(),
            next_group: 1,
            undo_stack: VecDeque::new(),
            redo_stack: Vec::new(),
            history_limit: 64,
        }
    }

    fn checkpoint(&mut self) {
        self.undo_stack.push_back(Snapshot {
            graph: self.graph.clone(),
            mapping: self.mapping.clone(),
            next_group: self.next_group,
        });
        if self.undo_stack.len() > self.history_limit {
            self.undo_stack.pop_front();
        }
        self.redo_stack.clear();
    }

    // ==================================================================
    // Graph transformation primitives
    // ==================================================================

    /// `group(tasks)` — tag tasks with a fresh group id so group-wide
    /// operations (`tile_group`) can address them together.
    pub fn group(&mut self, tasks: &[TaskId]) -> Result<u32> {
        for t in tasks {
            if !self.graph.contains(*t) {
                return err(format!("group: task {t} does not exist"));
            }
        }
        self.checkpoint();
        let gid = self.next_group;
        self.next_group += 1;
        for t in tasks {
            self.graph.task_mut(*t).group = gid;
        }
        Ok(gid)
    }

    /// `tile_task(task, tile_vector)` — split a compute or storage task into
    /// `prod(tile_vector)` tiles with proportionally divided cost. Each tile
    /// inherits the original's dependencies and placement; the original is
    /// deleted. Returns the tile ids.
    pub fn tile_task(&mut self, task: TaskId, tile: &[u32]) -> Result<Vec<TaskId>> {
        if !self.graph.contains(task) {
            return err(format!("tile_task: task {task} does not exist"));
        }
        if tile.is_empty() || tile.iter().any(|t| *t == 0) {
            return err(format!("tile_task: bad tile vector {tile:?}"));
        }
        let ntiles: u64 = tile.iter().map(|t| *t as u64).product();
        if ntiles == 1 {
            return Ok(vec![task]);
        }
        let original = self.graph.task(task).clone();
        let tiled_kind = |i: u64| -> Result<TaskKind> {
            match &original.kind {
                TaskKind::Compute(c) => {
                    let mut t = *c;
                    t.mac_flops /= ntiles as f64;
                    t.vec_flops /= ntiles as f64;
                    t.in_bytes = div_bytes(c.in_bytes, ntiles, i);
                    t.out_bytes = div_bytes(c.out_bytes, ntiles, i);
                    t.dram_bytes = div_bytes(c.dram_bytes, ntiles, i);
                    for (d, tv) in t.dims.iter_mut().zip(tile.iter()) {
                        if *d > 0 {
                            *d = (*d).div_ceil(*tv);
                        }
                    }
                    Ok(TaskKind::Compute(t))
                }
                TaskKind::Storage { bytes } => Ok(TaskKind::Storage {
                    bytes: div_bytes(*bytes, ntiles, i),
                }),
                TaskKind::Comm { bytes, hops, route } => Ok(TaskKind::Comm {
                    bytes: div_bytes(*bytes, ntiles, i),
                    hops: *hops,
                    route: route.clone(),
                }),
                TaskKind::Sync { .. } => err("tile_task: cannot tile a sync task"),
            }
        };
        // Validate before mutating.
        tiled_kind(0)?;
        self.checkpoint();

        let preds = self.graph.predecessors(task).to_vec();
        let succs = self.graph.successors(task).to_vec();
        let placement = self.mapping.point_of(task);
        let mut tiles = Vec::with_capacity(ntiles as usize);
        for i in 0..ntiles {
            let id = self
                .graph
                .add(format!("{}[{}]", original.name, i), tiled_kind(i).unwrap());
            self.graph.task_mut(id).group = original.group;
            self.graph.task_mut(id).enabled = original.enabled;
            for &p in &preds {
                self.graph.connect(p, id);
            }
            for &s in &succs {
                self.graph.connect(id, s);
            }
            if let Some(pt) = placement {
                self.mapping.map(id, pt);
            }
            if let Some(tc) = self.mapping.time_of(task).cloned() {
                self.mapping.set_time(id, tc);
            }
            tiles.push(id);
        }
        self.graph.remove(task);
        self.mapping.unmap(task);
        Ok(tiles)
    }

    /// `tile_group(group_id, tile_vector)` — tile every task in a group.
    pub fn tile_group(&mut self, group_id: u32, tile: &[u32]) -> Result<Vec<TaskId>> {
        let members: Vec<TaskId> = self
            .graph
            .iter()
            .filter(|t| t.group == group_id)
            .map(|t| t.id)
            .collect();
        if members.is_empty() {
            return err(format!("tile_group: empty group {group_id}"));
        }
        let mut out = Vec::new();
        for m in members {
            out.extend(self.tile_task(m, tile)?);
        }
        Ok(out)
    }

    /// `split_edge(task, number)` — split a communication task into `number`
    /// parallel sub-tasks sharing the data flux.
    pub fn split_edge(&mut self, task: TaskId, number: u32) -> Result<Vec<TaskId>> {
        match self.graph.get(task).map(|t| &t.kind) {
            Some(TaskKind::Comm { .. }) => {}
            Some(_) => return err(format!("split_edge: {task} is not a comm task")),
            None => return err(format!("split_edge: task {task} does not exist")),
        }
        self.tile_task(task, &[number])
    }

    /// `delete_task(task)` — remove a task and its edges.
    pub fn delete_task(&mut self, task: TaskId) -> Result<()> {
        if !self.graph.contains(task) {
            return err(format!("delete_task: task {task} does not exist"));
        }
        self.checkpoint();
        self.graph.remove(task);
        self.mapping.unmap(task);
        Ok(())
    }

    /// `copy_task(task)` — duplicate a task together with its dependencies
    /// and placement (used e.g. to replicate storage across memories).
    pub fn copy_task(&mut self, task: TaskId) -> Result<TaskId> {
        if !self.graph.contains(task) {
            return err(format!("copy_task: task {task} does not exist"));
        }
        self.checkpoint();
        let original = self.graph.task(task).clone();
        let id = self
            .graph
            .add(format!("{}'", original.name), original.kind.clone());
        self.graph.task_mut(id).group = original.group;
        for p in self.graph.predecessors(task).to_vec() {
            self.graph.connect(p, id);
        }
        for s in self.graph.successors(task).to_vec() {
            self.graph.connect(id, s);
        }
        if let Some(pt) = self.mapping.point_of(task) {
            self.mapping.map(id, pt);
        }
        Ok(id)
    }

    /// `connect(task1, task2)` — add a data dependency.
    pub fn connect(&mut self, a: TaskId, b: TaskId) -> Result<()> {
        if !self.graph.contains(a) || !self.graph.contains(b) {
            return err("connect: missing task");
        }
        if a == b {
            return err("connect: self dependency");
        }
        self.checkpoint();
        self.graph.connect(a, b);
        Ok(())
    }

    // ==================================================================
    // Task assignment primitives
    // ==================================================================

    /// `map_node(task, coord)` — place a task on a point.
    pub fn map_node(&mut self, task: TaskId, point: PointId) -> Result<()> {
        if !self.graph.contains(task) {
            return err(format!("map_node: task {task} does not exist"));
        }
        self.checkpoint();
        self.mapping.map(task, point);
        Ok(())
    }

    /// `take_out(task, coord)` — remove a task from the point it occupies.
    pub fn take_out(&mut self, task: TaskId, point: PointId) -> Result<()> {
        match self.mapping.point_of(task) {
            Some(p) if p == point => {
                self.checkpoint();
                self.mapping.unmap(task);
                Ok(())
            }
            Some(p) => err(format!("take_out: {task} is on {p}, not {point}")),
            None => err(format!("take_out: {task} is unmapped")),
        }
    }

    /// `map_edge(task, path, sub_paths)` — decompose a communication task
    /// into a chain of per-level sub-tasks, one per [`CommSegment`]
    /// (normally produced by [`crate::hwir::Hardware::route`]).
    ///
    /// The original task is detached and disabled; `take_edge_out` restores
    /// it. Returns the sub-task ids in path order. A route with no segments
    /// (same-point transfer) deletes the comm task and wires predecessors
    /// directly to successors.
    pub fn map_edge(&mut self, task: TaskId, segments: &[CommSegment]) -> Result<Vec<TaskId>> {
        let bytes = match self.graph.get(task).map(|t| &t.kind) {
            Some(TaskKind::Comm { bytes, .. }) => *bytes,
            Some(_) => return err(format!("map_edge: {task} is not a comm task")),
            None => return err(format!("map_edge: task {task} does not exist")),
        };
        if self.mapping.edge_decomposition(task).is_some() {
            return err(format!("map_edge: {task} already decomposed"));
        }
        self.checkpoint();
        let preds = self.graph.predecessors(task).to_vec();
        let succs = self.graph.successors(task).to_vec();
        let name = self.graph.task(task).name.clone();

        if segments.is_empty() {
            // Same-point transfer: zero-cost, collapse the edge.
            for &p in &preds {
                for &s in &succs {
                    self.graph.connect(p, s);
                }
                self.graph.disconnect(p, task);
            }
            for &s in &succs {
                self.graph.disconnect(task, s);
            }
            self.graph.task_mut(task).enabled = false;
            self.mapping.unmap(task);
            self.mapping.record_edge_decomposition(task, Vec::new());
            return Ok(Vec::new());
        }

        let mut subs = Vec::with_capacity(segments.len());
        for (i, seg) in segments.iter().enumerate() {
            let id = self.graph.add(
                format!("{name}/{i}"),
                TaskKind::Comm {
                    bytes,
                    hops: seg.hops,
                    route: Some((seg.from.clone(), seg.to.clone())),
                },
            );
            self.mapping.map(id, seg.comm);
            if let Some(prev) = subs.last().copied() {
                self.graph.connect(prev, id);
            }
            subs.push(id);
        }
        for &p in &preds {
            self.graph.connect(p, subs[0]);
            self.graph.disconnect(p, task);
        }
        for &s in &succs {
            self.graph.connect(*subs.last().unwrap(), s);
            self.graph.disconnect(task, s);
        }
        self.graph.task_mut(task).enabled = false;
        self.mapping.unmap(task);
        self.mapping.record_edge_decomposition(task, subs.clone());
        Ok(subs)
    }

    /// `take_edge_out(task, path)` — undo a `map_edge` decomposition,
    /// restoring the original communication task and its edges.
    pub fn take_edge_out(&mut self, task: TaskId) -> Result<()> {
        let subs = match self.mapping.edge_decomposition(task) {
            Some(s) => s.to_vec(),
            None => return err(format!("take_edge_out: {task} is not decomposed")),
        };
        self.checkpoint();
        self.mapping.take_edge_decomposition(task);
        if subs.is_empty() {
            // Collapsed same-point edge: we cannot recover which pred->succ
            // edges belonged to the comm task without records, so leave the
            // direct edges and simply re-enable.
            self.graph.task_mut(task).enabled = true;
            return Ok(());
        }
        let preds = self.graph.predecessors(subs[0]).to_vec();
        let succs = self.graph.successors(*subs.last().unwrap()).to_vec();
        for &p in &preds {
            if !subs.contains(&p) {
                self.graph.connect(p, task);
            }
        }
        for &s in &succs {
            if !subs.contains(&s) {
                self.graph.connect(task, s);
            }
        }
        for sub in subs {
            self.graph.remove(sub);
            self.mapping.unmap(sub);
        }
        self.graph.task_mut(task).enabled = true;
        Ok(())
    }

    // ==================================================================
    // Synchronization primitives
    // ==================================================================

    /// `sync(sync_id, coord)` — insert a `SyncTask` on a point. All sync
    /// tasks sharing `sync_id` form one barrier: each completes only when
    /// every member is ready.
    pub fn sync(&mut self, sync_id: u32, point: PointId) -> Result<TaskId> {
        self.checkpoint();
        let id = self
            .graph
            .add(format!("sync{sync_id}@{point}"), TaskKind::Sync { sync_id });
        self.mapping.map(id, point);
        Ok(id)
    }

    /// Convenience: a barrier across `points`, ordered after `after` and
    /// before `before`.
    pub fn barrier(
        &mut self,
        sync_id: u32,
        points: &[PointId],
        after: &[TaskId],
        before: &[TaskId],
    ) -> Result<Vec<TaskId>> {
        if points.is_empty() {
            return err("barrier: no points");
        }
        self.checkpoint();
        let mut ids = Vec::with_capacity(points.len());
        for &p in points {
            let id = self
                .graph
                .add(format!("sync{sync_id}@{p}"), TaskKind::Sync { sync_id });
            self.mapping.map(id, p);
            ids.push(id);
        }
        for &a in after {
            for &s in &ids {
                self.graph.connect(a, s);
            }
        }
        for &s in &ids {
            for &b in before {
                self.graph.connect(s, b);
            }
        }
        Ok(ids)
    }

    // ==================================================================
    // State control primitives
    // ==================================================================

    /// `enable(task)`.
    pub fn enable(&mut self, task: TaskId) -> Result<()> {
        self.set_enabled(task, true)
    }

    /// `disable(task)` — the simulator skips disabled tasks.
    pub fn disable(&mut self, task: TaskId) -> Result<()> {
        self.set_enabled(task, false)
    }

    fn set_enabled(&mut self, task: TaskId, on: bool) -> Result<()> {
        if !self.graph.contains(task) {
            return err(format!("enable/disable: task {task} does not exist"));
        }
        self.checkpoint();
        self.graph.task_mut(task).enabled = on;
        Ok(())
    }

    /// `undo()` — revert the most recent primitive. Returns false when the
    /// history is empty.
    pub fn undo(&mut self) -> bool {
        match self.undo_stack.pop_back() {
            Some(snap) => {
                self.redo_stack.push(Snapshot {
                    graph: std::mem::replace(&mut self.graph, snap.graph),
                    mapping: std::mem::replace(&mut self.mapping, snap.mapping),
                    next_group: std::mem::replace(&mut self.next_group, snap.next_group),
                });
                true
            }
            None => false,
        }
    }

    /// `redo()` — re-apply an undone primitive.
    pub fn redo(&mut self) -> bool {
        match self.redo_stack.pop() {
            Some(snap) => {
                self.undo_stack.push_back(Snapshot {
                    graph: std::mem::replace(&mut self.graph, snap.graph),
                    mapping: std::mem::replace(&mut self.mapping, snap.mapping),
                    next_group: std::mem::replace(&mut self.next_group, snap.next_group),
                });
                true
            }
            None => false,
        }
    }

    /// Depth of the undo history.
    pub fn history_len(&self) -> usize {
        self.undo_stack.len()
    }
}

/// Divide `bytes` into `n` near-equal parts; part `i` absorbs the remainder
/// so totals are conserved exactly.
fn div_bytes(bytes: u64, n: u64, i: u64) -> u64 {
    let base = bytes / n;
    if i == 0 {
        base + bytes % n
    } else {
        base
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hwir::{
        mlc, CommAttrs, ComputeAttrs, Coord, Element, Hardware, SpaceMatrix, SpacePoint, Topology,
    };
    use crate::taskgraph::{ComputeCost, OpClass};

    fn hw() -> Hardware {
        let mut chip = SpaceMatrix::new("chip", vec![2, 2]);
        for i in 0..2 {
            for j in 0..2 {
                chip.set(
                    Coord::new(vec![i, j]),
                    Element::Point(SpacePoint::compute("core", ComputeAttrs::new((4, 4), 8))),
                );
            }
        }
        chip.add_comm(SpacePoint::comm(
            "noc",
            CommAttrs::new(Topology::Mesh, 16.0, 1),
        ));
        let mut board = SpaceMatrix::new("board", vec![2]);
        board.set(Coord::new(vec![0]), Element::Matrix(chip.clone()));
        board.set(Coord::new(vec![1]), Element::Matrix(chip));
        board.add_comm(SpacePoint::comm(
            "bnet",
            CommAttrs::new(Topology::Ring, 8.0, 4),
        ));
        Hardware::build(board)
    }

    fn compute_cost(flops: f64) -> TaskKind {
        let mut c = ComputeCost::zero(OpClass::MatMul);
        c.mac_flops = flops;
        c.in_bytes = 1000;
        c.out_bytes = 100;
        c.dims = [64, 64, 64];
        TaskKind::Compute(c)
    }

    /// a --e--> b (comm task e between two computes)
    fn chain_state() -> (MappingState, TaskId, TaskId, TaskId) {
        let mut g = TaskGraph::new();
        let a = g.add("a", compute_cost(1000.0));
        let e = g.add("e", TaskKind::Comm { bytes: 4096, hops: 0, route: None });
        let b = g.add("b", compute_cost(1000.0));
        g.connect(a, e);
        g.connect(e, b);
        (MappingState::new(g), a, e, b)
    }

    #[test]
    fn group_and_tile_group() {
        let (mut st, a, _e, b) = chain_state();
        let gid = st.group(&[a, b]).unwrap();
        let tiles = st.tile_group(gid, &[2, 2]).unwrap();
        assert_eq!(tiles.len(), 8); // two tasks × 4 tiles
        assert!(tiles.iter().all(|t| st.graph.task(*t).group == gid));
        assert!(!st.graph.contains(a));
    }

    #[test]
    fn tile_task_divides_cost_and_preserves_totals() {
        let (mut st, a, _e, _b) = chain_state();
        let tiles = st.tile_task(a, &[2, 2]).unwrap();
        assert_eq!(tiles.len(), 4);
        let mut flops = 0.0;
        let mut in_bytes = 0;
        for t in &tiles {
            if let TaskKind::Compute(c) = &st.graph.task(*t).kind {
                flops += c.mac_flops;
                in_bytes += c.in_bytes;
                assert_eq!(c.dims, [32, 32, 64]); // m,n halved; k untouched
            }
        }
        assert!((flops - 1000.0).abs() < 1e-9);
        assert_eq!(in_bytes, 1000);
    }

    #[test]
    fn tile_task_rewires_edges() {
        let (mut st, a, e, _b) = chain_state();
        let tiles = st.tile_task(a, &[3]).unwrap();
        for t in &tiles {
            assert!(st.graph.successors(*t).contains(&e));
        }
        assert_eq!(st.graph.predecessors(e).len(), 3);
        assert!(st.graph.validate().is_empty());
    }

    #[test]
    fn tile_identity_is_noop() {
        let (mut st, a, _e, _b) = chain_state();
        assert_eq!(st.tile_task(a, &[1]).unwrap(), vec![a]);
        assert!(st.graph.contains(a));
    }

    #[test]
    fn split_edge_divides_bytes() {
        let (mut st, _a, e, b) = chain_state();
        let subs = st.split_edge(e, 3).unwrap();
        assert_eq!(subs.len(), 3);
        let total: u64 = subs
            .iter()
            .map(|t| match st.graph.task(*t).kind {
                TaskKind::Comm { bytes, .. } => bytes,
                _ => 0,
            })
            .sum();
        assert_eq!(total, 4096);
        for s in &subs {
            assert!(st.graph.successors(*s).contains(&b));
        }
    }

    #[test]
    fn split_edge_rejects_compute() {
        let (mut st, a, _e, _b) = chain_state();
        assert!(st.split_edge(a, 2).is_err());
    }

    #[test]
    fn copy_and_delete() {
        let (mut st, a, e, _b) = chain_state();
        let a2 = st.copy_task(a).unwrap();
        assert!(st.graph.successors(a2).contains(&e));
        st.delete_task(a).unwrap();
        assert!(!st.graph.contains(a));
        assert!(st.graph.contains(a2));
        assert!(st.graph.validate().is_empty());
    }

    #[test]
    fn map_and_take_out() {
        let hw = hw();
        let (mut st, a, _e, _b) = chain_state();
        let p = hw.cell(&mlc(&[&[0], &[0, 0]])).unwrap();
        st.map_node(a, p).unwrap();
        assert_eq!(st.mapping.point_of(a), Some(p));
        let q = hw.cell(&mlc(&[&[0], &[0, 1]])).unwrap();
        assert!(st.take_out(a, q).is_err()); // wrong point
        st.take_out(a, p).unwrap();
        assert_eq!(st.mapping.point_of(a), None);
    }

    #[test]
    fn map_edge_decomposes_cross_level() {
        let hw = hw();
        let (mut st, a, e, b) = chain_state();
        let src = mlc(&[&[0], &[1, 1]]);
        let dst = mlc(&[&[1], &[0, 1]]);
        st.map_node(a, hw.cell(&src).unwrap()).unwrap();
        st.map_node(b, hw.cell(&dst).unwrap()).unwrap();
        let segs = hw.route(&src, &dst);
        assert_eq!(segs.len(), 3); // noc0 up, bnet across, noc1 down
        let subs = st.map_edge(e, &segs).unwrap();
        assert_eq!(subs.len(), 3);
        // chain a -> s0 -> s1 -> s2 -> b
        assert!(st.graph.successors(a).contains(&subs[0]));
        assert!(st.graph.successors(subs[0]).contains(&subs[1]));
        assert!(st.graph.successors(subs[2]).contains(&b));
        assert!(!st.graph.task(e).enabled);
        assert!(st.graph.successors(a).len() == 1);
        // each sub sits on the right comm point
        for (sub, seg) in subs.iter().zip(&segs) {
            assert_eq!(st.mapping.point_of(*sub), Some(seg.comm));
        }
        // double decomposition rejected
        assert!(st.map_edge(e, &segs).is_err());
    }

    #[test]
    fn take_edge_out_restores() {
        let hw = hw();
        let (mut st, a, e, b) = chain_state();
        let src = mlc(&[&[0], &[1, 1]]);
        let dst = mlc(&[&[1], &[0, 1]]);
        let segs = hw.route(&src, &dst);
        let before_tasks = st.graph.len();
        st.map_edge(e, &segs).unwrap();
        st.take_edge_out(e).unwrap();
        assert_eq!(st.graph.len(), before_tasks);
        assert!(st.graph.task(e).enabled);
        assert!(st.graph.successors(a).contains(&e));
        assert!(st.graph.successors(e).contains(&b));
        assert!(st.graph.validate().is_empty());
    }

    #[test]
    fn sync_and_barrier() {
        let hw = hw();
        let (mut st, a, _e, b) = chain_state();
        let points: Vec<PointId> = hw.points_of_kind("compute")[..2].to_vec();
        let ids = st.barrier(7, &points, &[a], &[b]).unwrap();
        assert_eq!(ids.len(), 2);
        for s in &ids {
            assert!(st.graph.predecessors(*s).contains(&a));
            assert!(st.graph.successors(*s).contains(&b));
            assert!(matches!(
                st.graph.task(*s).kind,
                TaskKind::Sync { sync_id: 7 }
            ));
        }
    }

    #[test]
    fn enable_disable() {
        let (mut st, a, _e, _b) = chain_state();
        st.disable(a).unwrap();
        assert!(!st.graph.task(a).enabled);
        st.enable(a).unwrap();
        assert!(st.graph.task(a).enabled);
    }

    #[test]
    fn undo_redo_roundtrip() {
        let (mut st, a, _e, _b) = chain_state();
        let before = st.graph.clone();
        st.tile_task(a, &[4]).unwrap();
        let after = st.graph.clone();
        assert_ne!(before, after);
        assert!(st.undo());
        assert_eq!(st.graph, before);
        assert!(st.redo());
        assert_eq!(st.graph, after);
        assert!(!st.redo());
        // new action clears redo
        st.undo();
        st.copy_task(a).unwrap();
        assert!(!st.redo());
    }

    #[test]
    fn undo_depth_limit() {
        let (mut st, a, _e, _b) = chain_state();
        st.history_limit = 3;
        for _ in 0..5 {
            st.copy_task(a).unwrap();
        }
        assert_eq!(st.history_len(), 3);
    }

    #[test]
    fn history_eviction_drops_oldest_first() {
        // after overflowing the limit, undo steps back through the
        // *newest* checkpoints (the oldest were evicted from the front)
        let (mut st, a, _e, _b) = chain_state();
        st.history_limit = 2;
        st.copy_task(a).unwrap(); // checkpoint 1 (evicted)
        let after_two = {
            st.copy_task(a).unwrap(); // checkpoint 2
            st.graph.clone()
        };
        st.copy_task(a).unwrap(); // checkpoint 3
        assert!(st.undo());
        assert_eq!(st.graph, after_two);
        assert!(st.undo());
        assert!(!st.undo(), "oldest checkpoint must have been evicted");
    }

    #[test]
    fn map_error_propagates_into_error_chain_with_context() {
        use crate::util::error::Context;
        let (mut st, ..) = chain_state();
        let err: crate::util::error::Error = st
            .delete_task(TaskId(999))
            .context("applying mapping program")
            .unwrap_err();
        let msg = format!("{err:#}");
        assert_eq!(
            err.chain().len(),
            3,
            "context + 'mapping error' + detail: {msg}"
        );
        assert!(msg.starts_with("applying mapping program: mapping error:"), "{msg}");
        assert!(msg.contains("does not exist"), "{msg}");
    }

    #[test]
    fn prop_undo_restores_exactly() {
        use crate::util::propcheck::{check, Gen};
        check("random primitive then undo restores state", 48, |g: &mut Gen| {
            let (mut st, a, e, b) = chain_state();
            // apply a random prefix of primitives
            let prefix = g.usize(0..=3);
            for _ in 0..prefix {
                let _ = match g.usize(0..=2) {
                    0 => st.copy_task(a).map(|_| ()),
                    1 => st.split_edge(e, 2).map(|_| ()),
                    _ => st.connect(a, b).map(|_| ()),
                };
            }
            let graph_before = st.graph.clone();
            let mapping_before = st.mapping.clone();
            // one more primitive + undo
            let applied = match g.usize(0..=3) {
                0 => st.copy_task(a).is_ok(),
                1 => st.delete_task(b).is_ok(),
                2 => st.disable(a).is_ok(),
                _ => st.group(&[a, b]).is_ok(),
            };
            if applied && !st.undo() {
                return Err("undo failed after successful primitive".into());
            }
            if applied && (st.graph != graph_before || st.mapping != mapping_before) {
                return Err("undo did not restore state".into());
            }
            Ok(())
        });
    }
}
