//! `mldse` — command-line interface to the MLDSE infrastructure.
//!
//! ```text
//! mldse info                                   artifact + registry status
//! mldse simulate --arch dmc|gsm [--config N] [--seq N] [--pjrt] [--json]
//! mldse decode --mode temporal|spatial [--pos N] [--layers N] [--cpp N]
//! mldse experiment <name>|all [--quick] [--csv] | --list
//! mldse explore --space FILE.json|--preset NAME
//!               [--explorer grid|random|hill|anneal|anneal-tiered]
//!               [--budget N] [--workers N] [--seed N] [--top N] [--no-cache] [--json]
//!               [--checkpoint FILE [--checkpoint-every N]] [--resume FILE]
//!               [--deadline-events N] [--deadline-ms N]
//!               [--surrogate [--surrogate-warmup N] [--surrogate-keep PCT]
//!                [--surrogate-probe-every N]]
//! mldse serve [--port P] [--workers N] [--state-dir DIR] [--checkpoint-every N]
//!             [--max-connections N] [--read-timeout-ms N]
//!                                              exploration-as-a-service daemon
//! mldse bench run [--scenarios PATH] [--out FILE] [--quick] [--workers N]
//! mldse bench compare BASELINE.jsonl CURRENT.jsonl [--threshold PCT]
//! mldse bench list [--scenarios PATH]          declarative perf scenarios + gate
//! mldse check FILE.json... [--json] [--deny-warnings]   static diagnostics
//! mldse hardware --spec FILE                   build + describe a spec
//! ```
//!
//! (Hand-rolled argument parsing: the offline vendor set has no clap.)

use std::process::ExitCode;

use mldse::arch::{DmcParams, GsmParams, MpmcParams};
use mldse::bench::{
    compare_summaries, load_scenarios, run_scenario, CompareOpts, Summary, Verdict,
    DEFAULT_MAX_LOSS,
};
use mldse::coordinator::{Coordinator, EXPERIMENTS};
use mldse::cost::Packaging;
use mldse::dse::explore::{
    explorer_by_name, objectives_from_json, preset, preset_names, space_from_json_value,
    Checkpoint, DesignSpace, Edp, ExplorationReport, ExplorationSession, ExploreOpts, Makespan,
    Objective, SurrogateCfg,
};
use mldse::dse::parallel::resolve_workers;
use mldse::sim::SimConfig;
use mldse::util::error::{Context, Result};
use mldse::util::json::{Json, JsonObj};
use mldse::workloads::{
    dmc_decode_temporal, dmc_prefill, gsm_prefill, mpmc_decode_spatial, LlmConfig,
};

struct Args {
    positional: Vec<String>,
    flags: std::collections::HashMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Args {
        let mut positional = Vec::new();
        let mut flags = std::collections::HashMap::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                let value = if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    i += 1;
                    argv[i].clone()
                } else {
                    "true".to_string()
                };
                flags.insert(name.to_string(), value);
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        Args { positional, flags }
    }

    fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    fn bool_flag(&self, name: &str) -> bool {
        self.flag(name) == Some("true")
    }

    /// Parse a numeric flag; a missing flag yields the default, an
    /// unparsable value is an error naming the flag.
    fn num<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| mldse::format_err!("--{name}: invalid value '{v}'")),
        }
    }

    /// Reject flags the command does not define.
    fn allow(&self, cmd: &str, allowed: &[&str]) -> Result<()> {
        let mut unknown: Vec<&str> = self
            .flags
            .keys()
            .map(|k| k.as_str())
            .filter(|k| !allowed.contains(k))
            .collect();
        if unknown.is_empty() {
            return Ok(());
        }
        unknown.sort_unstable();
        let valid = if allowed.is_empty() {
            "none".to_string()
        } else {
            allowed
                .iter()
                .map(|f| format!("--{f}"))
                .collect::<Vec<_>>()
                .join(", ")
        };
        mldse::bail!(
            "unknown flag{s} {list} for '{cmd}' (valid: {valid})",
            s = if unknown.len() > 1 { "s" } else { "" },
            list = unknown
                .iter()
                .map(|f| format!("--{f}"))
                .collect::<Vec<_>>()
                .join(", ")
        )
    }
}

fn main() -> ExitCode {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        print_usage();
        return ExitCode::FAILURE;
    }
    let cmd = argv[0].clone();
    let args = Args::parse(&argv[1..]);
    let result = match cmd.as_str() {
        "info" => cmd_info(&args),
        "simulate" => cmd_simulate(&args),
        "decode" => cmd_decode(&args),
        "experiment" => cmd_experiment(&args),
        "explore" => cmd_explore(&args),
        "serve" => cmd_serve(&args),
        "bench" => cmd_bench(&args),
        "check" => cmd_check(&args),
        "hardware" => cmd_hardware(&args),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => {
            eprintln!("unknown command '{other}'");
            print_usage();
            return ExitCode::FAILURE;
        }
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

fn print_usage() {
    eprintln!(
        "mldse — Multi-Level Design Space Explorer\n\
         \n\
         commands:\n\
           info                                  runtime + artifact status\n\
           simulate --arch dmc|gsm [--config 1-4] [--seq N] [--pjrt] [--json] [--trace out.json]\n\
           decode --mode temporal|spatial [--pos N] [--layers N] [--cpp N] [--packaging mcm|2.5d]\n\
           experiment <{experiments}>|all [--quick] [--csv] | --list\n\
           explore --space FILE.json|--preset NAME\n\
                   [--explorer grid|random|hill|anneal|anneal-tiered]\n\
                   [--budget N] [--workers N] [--seed N] [--top N] [--no-cache] [--json]\n\
                   [--checkpoint FILE [--checkpoint-every N]] [--resume FILE]\n\
                   [--deadline-events N] [--deadline-ms N]\n\
                   [--surrogate [--surrogate-warmup N] [--surrogate-keep PCT]\n\
                    [--surrogate-probe-every N]]\n\
                   (presets: {presets}; --workers 0 = auto-detect,\n\
                    honoring the MLDSE_WORKERS environment override; space\n\
                    files compose param/packaging/product/nested spaces —\n\
                    see README \"Composable design spaces\"; --checkpoint\n\
                    writes a resumable snapshot every N steps, --resume\n\
                    restores one bit-identically; --deadline-events fails\n\
                    runaway candidates deterministically, --deadline-ms is\n\
                    the wall-clock backstop — see README \"Robustness &\n\
                    fault injection\"; --surrogate gates proposals through\n\
                    a learned model after --surrogate-warmup exact evals,\n\
                    keeping ~--surrogate-keep percent plus one forced probe\n\
                    every --surrogate-probe-every decisions — skipped\n\
                    candidates never reach the Pareto front, see README\n\
                    \"Surrogate-guided exploration\")\n\
           serve [--port P] [--workers N] [--state-dir DIR]\n\
                 [--checkpoint-every N] [--max-connections N]\n\
                 [--read-timeout-ms N]\n\
                   (exploration-as-a-service HTTP daemon on 127.0.0.1: job\n\
                    queue, JSONL event streams, pause/checkpoint/resume;\n\
                    --state-dir journals specs + periodic checkpoints so a\n\
                    killed daemon recovers its jobs bit-identically on\n\
                    restart; SIGTERM or POST /shutdown drains gracefully —\n\
                    see README \"Exploration as a service\")\n\
           bench run [--scenarios PATH] [--out FILE] [--quick] [--workers N]\n\
           bench compare BASELINE.jsonl CURRENT.jsonl [--threshold PCT]\n\
           bench list [--scenarios PATH]\n\
                   (declarative perf scenarios under benches/scenarios/;\n\
                    run emits a JSONL summary with bit-exact result\n\
                    fingerprints, compare gates throughput and\n\
                    determinism against a checked-in baseline — see README\n\
                    \"Benchmarks & regression gate\")\n\
           check FILE.json... [--json] [--deny-warnings]\n\
                   (static diagnostics over hardware specs, mapping\n\
                    programs, space files, and bench scenarios — stable\n\
                    MLDSE-Exxx/Wxxx codes, no simulation; --deny-warnings\n\
                    fails on warnings too — see README \"Static checks\")\n\
           hardware --spec FILE.json\n",
        experiments = EXPERIMENTS.join("|"),
        presets = preset_names().join(", ")
    );
}

fn cmd_info(args: &Args) -> Result<()> {
    args.allow("info", &[])?;
    println!("mldse {}", env!("CARGO_PKG_VERSION"));
    let art = mldse::runtime::artifacts_dir();
    println!("artifacts dir: {}", art.display());
    let eval_art = art.join("evaluator_b128.hlo.txt");
    println!(
        "evaluator artifact: {}",
        if eval_art.exists() { "present" } else { "MISSING (run `make artifacts`)" }
    );
    if eval_art.exists() {
        match Coordinator::with_pjrt() {
            Ok(_) => println!("PJRT runtime: ok"),
            Err(e) => println!("PJRT runtime: FAILED ({e:#})"),
        }
    }
    println!("experiments: {}", EXPERIMENTS.join(", "));
    println!("explore presets: {}", preset_names().join(", "));
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    args.allow(
        "simulate",
        &["arch", "config", "seq", "pjrt", "json", "trace", "iterations"],
    )?;
    let arch = args.flag("arch").unwrap_or("dmc");
    let config = args.num("config", 2usize)?;
    let seq = args.num("seq", 2048u32)?;
    let cfg = LlmConfig::gpt3_6_7b();
    let workload = match arch {
        "dmc" => dmc_prefill(&cfg, seq, &DmcParams::table2(config)?),
        "gsm" => gsm_prefill(&cfg, seq, &GsmParams::table2(config)?),
        other => mldse::bail!("unknown arch '{other}'"),
    };
    let coord = if args.bool_flag("pjrt") {
        Coordinator::with_pjrt()?
    } else {
        Coordinator::standard()
    };
    let sim_cfg = SimConfig {
        iterations: args.num("iterations", 1u32)?,
        collect_timeline: args.flag("trace").is_some(),
        ..Default::default()
    };
    let r = if args.bool_flag("pjrt") {
        coord.simulate_pjrt(&workload, &sim_cfg)?
    } else {
        coord.simulate(&workload, &sim_cfg)?
    };
    if args.bool_flag("json") {
        let mut o = JsonObj::new();
        o.insert("workload", workload.name.as_str().into());
        o.insert("makespan_cycles", r.makespan.into());
        o.insert("tasks_completed", r.completed.into());
        o.insert("truncations", r.truncations.into());
        o.insert(
            "notes",
            Json::Arr(workload.notes.iter().map(|n| n.as_str().into()).collect()),
        );
        println!("{}", Json::Obj(o).to_pretty());
    } else {
        println!("workload: {}", workload.name);
        for n in &workload.notes {
            println!("  note: {n}");
        }
        println!("makespan: {:.0} cycles", r.makespan);
        println!("tasks: {} completed, {} unfinished", r.completed, r.unfinished);
        println!("contention truncations: {}", r.truncations);
        println!(
            "energy: {:.3} mJ (avg power {:.1} W @1GHz)",
            r.total_energy() * 1e-9,
            r.avg_power_w(1.0)
        );
        if let Some((h, m)) = coord.pjrt_stats() {
            println!("pjrt cache: {h} hits / {m} misses");
        }
    }
    if let Some(path) = args.flag("trace") {
        let doc = mldse::sim::chrome_trace(&r, &workload.hw, &workload.graph);
        std::fs::write(path, doc.to_pretty())?;
        eprintln!("wrote Chrome trace to {path} (open in chrome://tracing)");
    }
    Ok(())
}

fn cmd_decode(args: &Args) -> Result<()> {
    args.allow("decode", &["mode", "pos", "layers", "cpp", "packaging"])?;
    let mode = args.flag("mode").unwrap_or("spatial");
    let pos = args.num("pos", 2048u32)?;
    let layers = args.num("layers", 8u32)?;
    let cfg = LlmConfig::gpt3_6_7b();
    let coord = Coordinator::standard();
    let w = match mode {
        "temporal" => dmc_decode_temporal(&cfg, pos, layers, &DmcParams::default()),
        "spatial" => {
            let cpp = args.num("cpp", 2usize)?;
            let pkg = match args.flag("packaging").unwrap_or("mcm") {
                "2.5d" | "interposer" => Packaging::Interposer2_5D,
                _ => Packaging::Mcm,
            };
            mpmc_decode_spatial(&cfg, pos, layers, &MpmcParams::paper(cpp, pkg))
        }
        other => mldse::bail!("unknown decode mode '{other}'"),
    };
    let r = coord.simulate(&w, &SimConfig::default())?;
    println!("workload: {}", w.name);
    for n in &w.notes {
        println!("  note: {n}");
    }
    println!("decode makespan: {:.0} cycles", r.makespan);
    Ok(())
}

fn cmd_experiment(args: &Args) -> Result<()> {
    args.allow("experiment", &["quick", "csv", "list"])?;
    if args.bool_flag("list") {
        for n in EXPERIMENTS {
            println!("{n}");
        }
        return Ok(());
    }
    let name = args
        .positional
        .first()
        .map(|s| s.as_str())
        .unwrap_or("all");
    if name != "all" && !EXPERIMENTS.contains(&name) {
        mldse::bail!(
            "unknown experiment '{name}'; valid: {}, or 'all' (see `mldse experiment --list`)",
            EXPERIMENTS.join(", ")
        );
    }
    let quick = args.bool_flag("quick");
    let coord = Coordinator::standard();
    let names: Vec<&str> = if name == "all" {
        EXPERIMENTS.to_vec()
    } else {
        vec![name]
    };
    for n in names {
        let tables = coord.run_experiment(n, quick)?;
        for t in tables {
            if args.bool_flag("csv") {
                println!("# {n}");
                print!("{}", t.to_csv());
            } else {
                println!("{}", t.render());
            }
        }
    }
    Ok(())
}

fn cmd_explore(args: &Args) -> Result<()> {
    args.allow(
        "explore",
        &[
            "space", "preset", "explorer", "budget", "workers", "seed", "json", "no-cache", "top",
            "checkpoint", "checkpoint-every", "resume", "deadline-events", "deadline-ms",
            "surrogate", "surrogate-warmup", "surrogate-keep", "surrogate-probe-every",
        ],
    )?;
    let (space, objectives): (Box<dyn DesignSpace>, Vec<Box<dyn Objective>>) =
        match (args.flag("space"), args.flag("preset")) {
            (Some(_), Some(_)) => {
                mldse::bail!("explore: --space and --preset are mutually exclusive")
            }
            (Some(path), None) => {
                let text = std::fs::read_to_string(path)
                    .with_context(|| format!("reading space file '{path}'"))?;
                let doc = mldse::util::json::Json::parse(&text)
                    .with_context(|| format!("parsing space file '{path}'"))?;
                // Fail-fast static pre-flight: named diagnostics before any
                // budget is spent; warnings surface but do not block.
                let diags = mldse::analyze::check_space_doc(&doc);
                if mldse::analyze::diag::has_errors(&diags) {
                    eprint!("{}", mldse::analyze::diag::render_table(path, &diags));
                    mldse::bail!("explore: space file '{path}' failed static checks");
                }
                for d in &diags {
                    eprintln!("{d}");
                }
                let s = space_from_json_value(&doc)
                    .with_context(|| format!("parsing space file '{path}'"))?;
                // the file may pick its own objectives; default (makespan,
                // EDP) otherwise
                let objs = objectives_from_json(&doc)
                    .with_context(|| format!("parsing space file '{path}'"))?
                    .unwrap_or_else(|| vec![Box::new(Makespan), Box::new(Edp)]);
                (s as Box<dyn DesignSpace>, objs)
            }
            (None, Some(name)) => preset(name)?,
            (None, None) => mldse::bail!(
                "explore: --space FILE.json or --preset NAME required (presets: {})",
                preset_names().join(", ")
            ),
        };
    // checkpoint/resume flags, validated with errors naming the flag
    let checkpoint_path = args.flag("checkpoint");
    if args.flag("checkpoint-every").is_some() && checkpoint_path.is_none() {
        mldse::bail!("--checkpoint-every requires --checkpoint FILE");
    }
    let checkpoint_every: u64 = args.num("checkpoint-every", 1u64)?;
    if checkpoint_every == 0 {
        mldse::bail!("--checkpoint-every: invalid value '0' (must be at least 1)");
    }
    let resume_path = args.flag("resume");
    if resume_path.is_some() {
        // these are baked into the checkpoint; supplying them again would
        // silently disagree with what actually resumes
        for flag in [
            "explorer",
            "budget",
            "seed",
            "no-cache",
            "surrogate",
            "surrogate-warmup",
            "surrogate-keep",
            "surrogate-probe-every",
        ] {
            if args.flag(flag).is_some() {
                mldse::bail!(
                    "--{flag} conflicts with --resume (the checkpoint fixes it; drop --{flag})"
                );
            }
        }
    }
    // surrogate sub-knobs are meaningless without the master switch
    if !args.bool_flag("surrogate") {
        for flag in ["surrogate-warmup", "surrogate-keep", "surrogate-probe-every"] {
            if args.flag(flag).is_some() {
                mldse::bail!("--{flag} requires --surrogate");
            }
        }
    }
    let ckpt = match resume_path {
        Some(path) => {
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("reading checkpoint '{path}'"))?;
            let doc = Json::parse(&text)
                .with_context(|| format!("parsing checkpoint '{path}'"))?;
            Some(
                Checkpoint::from_json(&doc)
                    .with_context(|| format!("parsing checkpoint '{path}'"))?,
            )
        }
        None => None,
    };
    let explorer_name = match &ckpt {
        Some(c) => c.explorer.clone(),
        None => args.flag("explorer").unwrap_or("grid").to_string(),
    };
    let seed = args.num("seed", 0xD5Eu64)?;
    let explorer = explorer_by_name(&explorer_name, seed)?;
    let default_budget = if explorer_name == "grid" {
        space.size().min(1024) as usize
    } else {
        64
    };
    // --workers 0 (or omitting the flag) auto-detects: the MLDSE_WORKERS
    // environment override when set (validated), else available cores.
    let workers = resolve_workers(args.num("workers", 0usize)?)?;
    // --surrogate-keep takes a percentage (35 = keep the best-scoring
    // ~35% of post-warmup proposals); the config stores the fraction.
    let surrogate = if args.bool_flag("surrogate") {
        let mut cfg = SurrogateCfg::with_seed(seed);
        cfg.warmup = args.num("surrogate-warmup", cfg.warmup)?;
        let keep_pct: f64 = args.num("surrogate-keep", cfg.keep * 100.0)?;
        cfg.keep = keep_pct / 100.0;
        cfg.probe_every = args.num("surrogate-probe-every", cfg.probe_every)?;
        cfg.validate()?;
        Some(cfg)
    } else {
        None
    };
    let mut opts = ExploreOpts {
        budget: args.num("budget", default_budget)?,
        workers,
        cache: !args.bool_flag("no-cache"),
        surrogate,
        ..Default::default()
    };
    // Per-candidate evaluation deadlines: the event budget is
    // deterministic (same verdict on every machine), the wall-clock cap
    // is a backstop. Runaway candidates surface as evaluation errors,
    // not hung runs. Mutate the defaulted `sim` rather than rebuilding
    // it so explore's other simulator defaults stay untouched.
    opts.sim.deadline_events = args.num("deadline-events", opts.sim.deadline_events)?;
    opts.sim.deadline_ms = args.num("deadline-ms", opts.sim.deadline_ms)?;
    let top = args.num("top", 10usize)?;
    let registry = mldse::eval::Registry::standard();
    let start = std::time::Instant::now();
    let report = std::thread::scope(|scope| -> Result<ExplorationReport> {
        let mut session = match ckpt {
            Some(c) => ExplorationSession::resume_in(
                scope,
                space.as_ref(),
                &objectives,
                explorer.as_ref(),
                &registry,
                &opts,
                c,
                None,
            )?,
            None => ExplorationSession::new_in(
                scope,
                space.as_ref(),
                &objectives,
                explorer.as_ref(),
                &registry,
                &opts,
                None,
            )?,
        };
        let mut last_saved = session.batches_done();
        while session.step() {
            if let Some(path) = checkpoint_path {
                if session.batches_done() - last_saved >= checkpoint_every {
                    write_checkpoint(path, &session)?;
                    last_saved = session.batches_done();
                }
            }
        }
        // always leave a final snapshot so a completed run resumes to an
        // identical report
        if let Some(path) = checkpoint_path {
            write_checkpoint(path, &session)?;
        }
        Ok(session.into_report(start.elapsed().as_secs_f64()))
    })?;
    if args.bool_flag("json") {
        println!("{}", report.to_json().to_pretty());
    } else {
        println!("{}", report.summary_table().render());
        println!("{}", report.pareto_table().render());
        if top > 0 {
            println!("{}", report.top_table(top).render());
        }
    }
    Ok(())
}

/// Serialize the session's current state to `path` (pretty JSON,
/// written atomically — a crash mid-write leaves the previous snapshot
/// intact instead of a torn file).
fn write_checkpoint(path: &str, session: &ExplorationSession<'_, '_>) -> Result<()> {
    mldse::util::atomic_write(
        std::path::Path::new(path),
        format!("{}\n", session.checkpoint().to_json().to_pretty()).as_bytes(),
    )
    .with_context(|| format!("writing checkpoint '{path}'"))
}

fn cmd_serve(args: &Args) -> Result<()> {
    args.allow(
        "serve",
        &[
            "port", "workers", "state-dir", "checkpoint-every", "max-connections",
            "read-timeout-ms",
        ],
    )?;
    let port = args.num("port", 8463u16)?;
    // per-job evaluation workers for jobs that do not request their own
    let workers = resolve_workers(args.num("workers", 0usize)?)?;
    let defaults = mldse::serve::ServeOpts::default();
    let max_connections = args.num("max-connections", defaults.max_connections)?;
    if max_connections == 0 {
        mldse::bail!("--max-connections: invalid value '0' (must be at least 1)");
    }
    let read_timeout_ms: u64 = args.num(
        "read-timeout-ms",
        defaults.read_timeout.as_millis() as u64,
    )?;
    if read_timeout_ms == 0 {
        mldse::bail!("--read-timeout-ms: invalid value '0' (must be at least 1)");
    }
    let opts = mldse::serve::ServeOpts {
        state_dir: args.flag("state-dir").map(std::path::PathBuf::from),
        checkpoint_every: args.num("checkpoint-every", defaults.checkpoint_every)?,
        max_connections,
        read_timeout: std::time::Duration::from_millis(read_timeout_ms),
        ..defaults
    };
    let recovering = opts.state_dir.is_some();
    let server = mldse::serve::Server::bind_with(port, workers, opts)?;
    println!(
        "mldse serve: listening on http://127.0.0.1:{} ({workers} evaluation workers per job{})",
        server.port(),
        if recovering { ", crash recovery on" } else { "" }
    );
    use std::io::Write;
    std::io::stdout().flush().ok();
    server.run()
}

fn cmd_bench(args: &Args) -> Result<()> {
    match args.positional.first().map(|s| s.as_str()) {
        Some("run") => bench_run(args),
        Some("compare") => bench_compare(args),
        Some("list") => bench_list(args),
        Some(other) => mldse::bail!(
            "bench: unknown subcommand '{other}' (valid: run, compare, list)"
        ),
        None => mldse::bail!("bench: a subcommand is required (run, compare, list)"),
    }
}

/// The scenario source: `--scenarios PATH` when given, else
/// `benches/scenarios` relative to the working directory, else the
/// crate's own scenario set (so the binary works from any directory).
fn bench_scenarios_path(args: &Args) -> std::path::PathBuf {
    if let Some(p) = args.flag("scenarios") {
        return std::path::PathBuf::from(p);
    }
    let local = std::path::Path::new("benches/scenarios");
    if local.exists() {
        return local.to_path_buf();
    }
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("benches/scenarios")
}

/// Quick mode: `--quick`, or `MLDSE_BENCH_QUICK=1` in the environment
/// (how CI shrinks the gate to smoke-test budgets).
fn bench_quick(args: &Args) -> bool {
    args.bool_flag("quick")
        || std::env::var("MLDSE_BENCH_QUICK").map(|v| v == "1").unwrap_or(false)
}

fn bench_run(args: &Args) -> Result<()> {
    args.allow("bench run", &["scenarios", "out", "quick", "workers"])?;
    let quick = bench_quick(args);
    // --workers overrides every scenario's own worker count (0 = auto)
    let workers_override = match args.flag("workers") {
        Some(_) => Some(args.num("workers", 0usize)?),
        None => None,
    };
    let scenarios = load_scenarios(&bench_scenarios_path(args))?;
    // Fail-fast static pre-flight over the whole set before any scenario
    // runs: a bad scenario at position N must not waste the first N-1 runs.
    let mut preflight = Vec::new();
    for s in &scenarios {
        for mut d in mldse::analyze::check_scenario(s) {
            d.at = if d.at.is_empty() {
                s.name.clone()
            } else {
                format!("{}: {}", s.name, d.at)
            };
            preflight.push(d);
        }
    }
    if mldse::analyze::diag::has_errors(&preflight) {
        eprint!(
            "{}",
            mldse::analyze::diag::render_table("bench scenarios", &preflight)
        );
        mldse::bail!("bench: scenario set failed static checks");
    }
    for d in &preflight {
        eprintln!("{d}");
    }
    let mut results = Vec::with_capacity(scenarios.len());
    for s in &scenarios {
        eprintln!(
            "bench: {} ({}, explorer {}, budget {}, {} seed(s)){}",
            s.name,
            s.family.name(),
            s.explorer,
            s.effective_budget(quick),
            s.seeds.len(),
            if quick { " [quick]" } else { "" }
        );
        let r = run_scenario(s, quick, workers_override)?;
        let skipped = match r.skipped_total() {
            0 => String::new(),
            n => format!(", {n} skipped by surrogate"),
        };
        eprintln!(
            "bench:   {} evals in {:.2}s ({:.1} evals/sec){}, fingerprint {:016x}",
            r.evals_total(),
            r.wall_secs,
            r.evals_per_sec(),
            skipped,
            r.fingerprint
        );
        results.push(r);
    }
    let summary = Summary::new(quick, &results);
    match args.flag("out") {
        Some(path) => {
            summary.write(std::path::Path::new(path))?;
            eprintln!("bench: wrote summary to {path}");
        }
        None => print!("{}", summary.to_jsonl()),
    }
    Ok(())
}

fn bench_compare(args: &Args) -> Result<()> {
    args.allow("bench compare", &["threshold"])?;
    let (base_path, cur_path) = match (args.positional.get(1), args.positional.get(2)) {
        (Some(b), Some(c)) => (b.as_str(), c.as_str()),
        _ => mldse::bail!("bench compare: usage: bench compare BASELINE.jsonl CURRENT.jsonl [--threshold PCT]"),
    };
    let threshold_pct: f64 = args.num("threshold", DEFAULT_MAX_LOSS * 100.0)?;
    if !threshold_pct.is_finite() || threshold_pct < 0.0 {
        mldse::bail!("--threshold: invalid value '{threshold_pct}' (want a percentage >= 0)");
    }
    let baseline = Summary::read(std::path::Path::new(base_path))?;
    let current = Summary::read(std::path::Path::new(cur_path))?;
    let report = compare_summaries(
        &baseline,
        &current,
        &CompareOpts {
            max_loss: threshold_pct / 100.0,
        },
    )?;
    print!("{}", report.render());
    if report.verdict() == Verdict::Fail {
        mldse::bail!("bench compare: regression detected (per-scenario diagnosis above)");
    }
    Ok(())
}

fn bench_list(args: &Args) -> Result<()> {
    args.allow("bench list", &["scenarios", "quick"])?;
    let quick = bench_quick(args);
    let path = bench_scenarios_path(args);
    let scenarios = load_scenarios(&path)?;
    let mut t = mldse::dse::report::Table::new(
        format!("Bench scenarios ({})", path.display()),
        &["name", "family", "explorer", "budget", "seeds", "workers", "file"],
    );
    for s in &scenarios {
        t.row(vec![
            s.name.clone(),
            s.family.name().to_string(),
            s.explorer.clone(),
            s.effective_budget(quick).to_string(),
            s.seeds.len().to_string(),
            s.workers.to_string(),
            s.origin.clone(),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

fn cmd_check(args: &Args) -> Result<()> {
    args.allow("check", &["json", "deny-warnings"])?;
    if args.positional.is_empty() {
        mldse::bail!(
            "check: at least one FILE.json is required (a hardware spec, mapping \
             program, space file, or bench scenario)"
        );
    }
    let as_json = args.bool_flag("json");
    let deny = args.bool_flag("deny-warnings");
    let mut total_errors = 0usize;
    let mut total_warnings = 0usize;
    let mut payloads = Vec::new();
    for path in &args.positional {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("check: reading '{path}'"))?;
        let (kind, diags) = mldse::analyze::check_text(&text, path);
        let (errors, warnings) = mldse::analyze::diag::counts(&diags);
        total_errors += errors;
        total_warnings += warnings;
        if as_json {
            // Same payload shape as the daemon's HTTP 422 body, plus the
            // sniffed input kind.
            let Json::Obj(mut o) = mldse::analyze::diag::to_json(path, &diags) else {
                unreachable!("diagnostic payload is an object");
            };
            if let Some(k) = kind {
                o.insert("kind", k.name().into());
            }
            payloads.push(Json::Obj(o));
        } else {
            match kind {
                Some(k) if diags.is_empty() => println!("check {path}: ok ({})", k.name()),
                _ => print!("{}", mldse::analyze::diag::render_table(path, &diags)),
            }
        }
    }
    if as_json {
        match &payloads[..] {
            [one] => println!("{}", one.to_pretty()),
            many => println!("{}", Json::Arr(many.to_vec()).to_pretty()),
        }
    }
    if total_errors > 0 || (deny && total_warnings > 0) {
        mldse::bail!(
            "check: {total_errors} error(s), {total_warnings} warning(s){}",
            if total_errors == 0 {
                " (failing because of --deny-warnings)"
            } else {
                ""
            }
        );
    }
    Ok(())
}

fn cmd_hardware(args: &Args) -> Result<()> {
    args.allow("hardware", &["spec"])?;
    let path = args
        .flag("spec")
        .ok_or_else(|| mldse::format_err!("--spec FILE required"))?;
    let text = std::fs::read_to_string(path)?;
    let matrix = mldse::hwir::parse_spec(&text)?;
    let hw = mldse::hwir::Hardware::build(matrix);
    println!("points: {}", hw.num_points());
    for kind in ["compute", "memory", "dram", "comm"] {
        println!("  {kind}: {}", hw.points_of_kind(kind).len());
    }
    println!("depth: {} levels", hw.root.depth());
    println!("sync groups: {}", hw.sync_groups().len());
    Ok(())
}
