//! Multi-package multi-chiplet DMC (MPMC-DMC) architecture (paper §7.4,
//! Fig. 10(a)).
//!
//! Spatial hierarchy: board → package → chiplet → core. A fixed pool of
//! DMC chiplets (24 in the paper, 128 cores / 128 MB each) is distributed
//! over packages; raising `chiplets_per_package` replaces slow board-level
//! links with fast in-package NoP links (MCM or 2.5D) at higher packaging
//! cost — the Fig. 10(c,d) trade-off. This template is the paper's
//! demonstration that MLDSE can *add a spatial level* without a new tool.

use crate::cost::{AreaModel, CostModel, Packaging};
use crate::hwir::{CommAttrs, Coord, Element, Hardware, SpaceMatrix, SpacePoint, Topology};

use super::dmc::DmcParams;

/// MPMC-DMC design parameters.
#[derive(Debug, Clone)]
pub struct MpmcParams {
    /// Total chiplet pool (must divide by `chiplets_per_package`).
    pub total_chiplets: usize,
    pub chiplets_per_package: usize,
    /// Per-chiplet DMC design (DRAM disabled; spatial computing keeps
    /// weights and KV on-chip, §7.4).
    pub chiplet: DmcParams,
    pub packaging: Packaging,
    /// In-package network-on-package.
    pub nop_bandwidth: f64,
    pub nop_latency: u64,
    /// Board-level network between packages.
    pub board_bandwidth: f64,
    pub board_latency: u64,
}

impl MpmcParams {
    /// The paper's §7.4 instance: 24 chiplets of 128 cores / 1 MB-per-core
    /// (128 MB on-chip each).
    pub fn paper(chiplets_per_package: usize, packaging: Packaging) -> MpmcParams {
        let chiplet = DmcParams {
            grid: (16, 8),
            lmem_capacity: 1 << 20, // 128 MB per chiplet
            with_dram: false,
            ..DmcParams::default()
        };
        let (nop_bw, nop_lat) = match packaging {
            Packaging::Mcm => (64.0, 8),
            Packaging::Interposer2_5D => (256.0, 3),
        };
        MpmcParams {
            total_chiplets: 24,
            chiplets_per_package,
            chiplet,
            packaging,
            nop_bandwidth: nop_bw,
            nop_latency: nop_lat,
            board_bandwidth: 4.0,
            board_latency: 2500, // PCB SerDes + protocol + switch stack
        }
    }

    pub fn packages(&self) -> usize {
        assert!(
            self.total_chiplets % self.chiplets_per_package == 0,
            "{} chiplets not divisible into packages of {}",
            self.total_chiplets,
            self.chiplets_per_package
        );
        self.total_chiplets / self.chiplets_per_package
    }

    /// Build `board -> package -> chiplet -> core`.
    pub fn build(&self) -> Hardware {
        let chip = self.chiplet.chip_matrix("chiplet");
        let mut package = SpaceMatrix::new("package", vec![self.chiplets_per_package]);
        for i in 0..self.chiplets_per_package {
            package.set(Coord::new(vec![i as u32]), Element::Matrix(chip.clone()));
        }
        package.add_comm(SpacePoint::comm(
            "nop",
            CommAttrs::new(
                match self.packaging {
                    Packaging::Mcm => Topology::Bus,
                    Packaging::Interposer2_5D => Topology::FullyConnected,
                },
                self.nop_bandwidth,
                self.nop_latency,
            ),
        ));

        let npkg = self.packages();
        let mut board = SpaceMatrix::new("board", vec![npkg]);
        for i in 0..npkg {
            board.set(Coord::new(vec![i as u32]), Element::Matrix(package.clone()));
        }
        board.add_comm(SpacePoint::comm(
            "board-net",
            CommAttrs::new(Topology::Ring, self.board_bandwidth, self.board_latency),
        ));
        Hardware::build(board)
    }

    /// Manufacturing cost of the whole system.
    pub fn system_cost(&self, area_model: &AreaModel, cost_model: &CostModel) -> f64 {
        let chiplet_area = self.chiplet.area(area_model).3;
        cost_model.system_cost(
            self.total_chiplets,
            self.chiplets_per_package,
            chiplet_area,
            self.packaging,
        )
    }

    /// Flat list of chiplet coordinates (board, package) in order — the
    /// unit the layer-pipeline mapper distributes transformer stages over.
    pub fn chiplet_coords(&self) -> Vec<crate::hwir::MlCoord> {
        let mut out = Vec::new();
        for p in 0..self.packages() {
            for c in 0..self.chiplets_per_package {
                out.push(crate::hwir::MlCoord::new(vec![
                    Coord::new(vec![p as u32]),
                    Coord::new(vec![c as u32]),
                ]));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hwir::mlc;

    #[test]
    fn four_level_hierarchy() {
        let p = MpmcParams::paper(2, Packaging::Mcm);
        let hw = p.build();
        assert_eq!(p.packages(), 12);
        // 24 chiplets * 128 cores
        assert_eq!(hw.points_of_kind("compute").len(), 24 * 128);
        // core at board(3) -> package(1) -> core(15,7)
        assert!(hw.cell(&mlc(&[&[3], &[1], &[15, 7]])).is_some());
        // comm points: 1 board-net + 12 nop + 24 noc
        assert_eq!(hw.points_of_kind("comm").len(), 1 + 12 + 24);
    }

    #[test]
    fn cross_package_route_uses_board_net() {
        let p = MpmcParams::paper(2, Packaging::Mcm);
        let hw = p.build();
        let segs = hw.route(
            &mlc(&[&[0], &[0], &[0, 0]]),
            &mlc(&[&[5], &[1], &[2, 3]]),
        );
        let names: Vec<&str> = segs.iter().map(|s| hw.point(s.comm).name.as_str()).collect();
        assert_eq!(names, ["noc", "nop", "board-net", "nop", "noc"]);
    }

    #[test]
    fn within_package_route_skips_board() {
        let p = MpmcParams::paper(4, Packaging::Interposer2_5D);
        let hw = p.build();
        let segs = hw.route(
            &mlc(&[&[0], &[0], &[0, 0]]),
            &mlc(&[&[0], &[3], &[0, 0]]),
        );
        let names: Vec<&str> = segs.iter().map(|s| hw.point(s.comm).name.as_str()).collect();
        assert_eq!(names, ["noc", "nop", "noc"]);
    }

    #[test]
    fn more_chiplets_per_package_costs_more() {
        let am = AreaModel::default();
        let cm = CostModel::default();
        let c1 = MpmcParams::paper(1, Packaging::Mcm).system_cost(&am, &cm);
        let c6 = MpmcParams::paper(6, Packaging::Mcm).system_cost(&am, &cm);
        assert!(c6 > c1);
        // 2.5D costs more than MCM at the same configuration
        let mcm = MpmcParams::paper(2, Packaging::Mcm).system_cost(&am, &cm);
        let d25 = MpmcParams::paper(2, Packaging::Interposer2_5D).system_cost(&am, &cm);
        assert!(d25 > mcm);
    }

    #[test]
    fn chiplet_coords_enumeration() {
        let p = MpmcParams::paper(3, Packaging::Mcm);
        let coords = p.chiplet_coords();
        assert_eq!(coords.len(), 24);
        assert_eq!(coords[0], mlc(&[&[0], &[0]]));
        assert_eq!(coords[23], mlc(&[&[7], &[2]]));
    }

    #[test]
    #[should_panic(expected = "not divisible")]
    fn invalid_chiplet_split_panics() {
        MpmcParams::paper(5, Packaging::Mcm).packages();
    }
}
