//! `SpacePoint` — the finest-grained modeled hardware element.
//!
//! A `SpacePoint` does not contain other elements (paper §4). It is one of:
//! a compute unit, a memory, an external DRAM channel, or a communication
//! domain (NoC/NoP/bus/...). Each point carries typed attributes consumed by
//! the evaluators, and at simulation time owns a task queue (compute/comm)
//! or a storage pool (memory) — those live in the simulator, not here.

use super::topology::Topology;

/// Typed attributes of a compute `SpacePoint`.
#[derive(Debug, Clone, PartialEq)]
pub struct ComputeAttrs {
    /// Systolic array dimensions (rows, cols); `(0, 0)` when absent.
    pub systolic: (u32, u32),
    /// Vector unit lanes (FLOPs/cycle on vector work = 2 * lanes for FMA).
    pub vector_lanes: u32,
    /// MAC throughput of the systolic array per cycle (rows*cols) — derived.
    pub macs_per_cycle: u64,
    /// Local memory feeding this unit (DMC core SRAM; GSM L1+register
    /// file). `None` models a pure ALU fed entirely by explicit transfers.
    pub lmem: Option<MemoryAttrs>,
}

impl ComputeAttrs {
    pub fn new(systolic: (u32, u32), vector_lanes: u32) -> Self {
        ComputeAttrs {
            systolic,
            vector_lanes,
            macs_per_cycle: systolic.0 as u64 * systolic.1 as u64,
            lmem: None,
        }
    }

    /// Attach a local memory.
    pub fn with_lmem(mut self, lmem: MemoryAttrs) -> Self {
        self.lmem = Some(lmem);
        self
    }

    /// Peak matrix FLOPs/cycle (2 per MAC).
    pub fn matrix_flops_per_cycle(&self) -> f64 {
        2.0 * self.macs_per_cycle as f64
    }

    /// Peak vector FLOPs/cycle (2 per lane, FMA).
    pub fn vector_flops_per_cycle(&self) -> f64 {
        2.0 * self.vector_lanes as f64
    }
}

/// Typed attributes of a memory `SpacePoint` (on-chip SRAM levels and DRAM).
#[derive(Debug, Clone, PartialEq)]
pub struct MemoryAttrs {
    /// Capacity in bytes.
    pub capacity: u64,
    /// Bandwidth in bytes/cycle.
    pub bandwidth: f64,
    /// Access latency in cycles.
    pub latency: u64,
}

impl MemoryAttrs {
    pub fn new(capacity: u64, bandwidth: f64, latency: u64) -> Self {
        MemoryAttrs {
            capacity,
            bandwidth,
            latency,
        }
    }
}

/// Typed attributes of a communication `SpacePoint` (one communication
/// domain of a `SpaceMatrix`).
#[derive(Debug, Clone, PartialEq)]
pub struct CommAttrs {
    pub topology: Topology,
    /// Per-link bandwidth in bytes/cycle.
    pub link_bandwidth: f64,
    /// Per-hop latency in cycles.
    pub link_latency: u64,
}

impl CommAttrs {
    pub fn new(topology: Topology, link_bandwidth: f64, link_latency: u64) -> Self {
        CommAttrs {
            topology,
            link_bandwidth,
            link_latency,
        }
    }
}

/// The role + attributes of a `SpacePoint`.
#[derive(Debug, Clone, PartialEq)]
pub enum PointKind {
    Compute(ComputeAttrs),
    Memory(MemoryAttrs),
    /// Off-chip DRAM attached at this level (modeled as a memory with
    /// channel semantics: contended bandwidth).
    Dram(MemoryAttrs),
    Comm(CommAttrs),
}

impl PointKind {
    pub fn kind_name(&self) -> &'static str {
        match self {
            PointKind::Compute(_) => "compute",
            PointKind::Memory(_) => "memory",
            PointKind::Dram(_) => "dram",
            PointKind::Comm(_) => "comm",
        }
    }

    pub fn is_compute(&self) -> bool {
        matches!(self, PointKind::Compute(_))
    }
    pub fn is_memory(&self) -> bool {
        matches!(self, PointKind::Memory(_) | PointKind::Dram(_))
    }
    pub fn is_comm(&self) -> bool {
        matches!(self, PointKind::Comm(_))
    }

    pub fn as_compute(&self) -> Option<&ComputeAttrs> {
        match self {
            PointKind::Compute(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_memory(&self) -> Option<&MemoryAttrs> {
        match self {
            PointKind::Memory(a) | PointKind::Dram(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_comm(&self) -> Option<&CommAttrs> {
        match self {
            PointKind::Comm(a) => Some(a),
            _ => None,
        }
    }
}

/// The finest-grained modeled hardware element.
#[derive(Debug, Clone, PartialEq)]
pub struct SpacePoint {
    /// Human-readable role name (e.g. "core", "lmem", "noc", "dram").
    pub name: String,
    pub kind: PointKind,
    /// Evaluator binding key; resolved by `eval::Registry`. Empty = default
    /// evaluator for the kind.
    pub evaluator: String,
}

impl SpacePoint {
    pub fn compute(name: impl Into<String>, attrs: ComputeAttrs) -> Self {
        SpacePoint {
            name: name.into(),
            kind: PointKind::Compute(attrs),
            evaluator: String::new(),
        }
    }

    pub fn memory(name: impl Into<String>, attrs: MemoryAttrs) -> Self {
        SpacePoint {
            name: name.into(),
            kind: PointKind::Memory(attrs),
            evaluator: String::new(),
        }
    }

    pub fn dram(name: impl Into<String>, attrs: MemoryAttrs) -> Self {
        SpacePoint {
            name: name.into(),
            kind: PointKind::Dram(attrs),
            evaluator: String::new(),
        }
    }

    pub fn comm(name: impl Into<String>, attrs: CommAttrs) -> Self {
        SpacePoint {
            name: name.into(),
            kind: PointKind::Comm(attrs),
            evaluator: String::new(),
        }
    }

    pub fn with_evaluator(mut self, key: impl Into<String>) -> Self {
        self.evaluator = key.into();
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compute_attrs_derive_throughput() {
        let a = ComputeAttrs::new((128, 128), 512);
        assert_eq!(a.macs_per_cycle, 16384);
        assert_eq!(a.matrix_flops_per_cycle(), 32768.0);
        assert_eq!(a.vector_flops_per_cycle(), 1024.0);
    }

    #[test]
    fn kind_predicates() {
        let c = SpacePoint::compute("core", ComputeAttrs::new((4, 4), 8));
        let m = SpacePoint::memory("lmem", MemoryAttrs::new(1 << 20, 64.0, 2));
        let d = SpacePoint::dram("dram", MemoryAttrs::new(1 << 33, 128.0, 100));
        let n = SpacePoint::comm(
            "noc",
            CommAttrs::new(Topology::Mesh, 32.0, 1),
        );
        assert!(c.kind.is_compute() && !c.kind.is_memory());
        assert!(m.kind.is_memory() && d.kind.is_memory());
        assert!(n.kind.is_comm());
        assert_eq!(d.kind.kind_name(), "dram");
        assert!(m.kind.as_memory().is_some());
        assert!(m.kind.as_comm().is_none());
    }

    #[test]
    fn evaluator_binding() {
        let p = SpacePoint::compute("core", ComputeAttrs::new((2, 2), 4)).with_evaluator("pjrt");
        assert_eq!(p.evaluator, "pjrt");
    }
}
