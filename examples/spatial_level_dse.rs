//! Spatial-level DSE (paper §7.4): start from a temporally-mapped DMC
//! decode baseline, then *add a spatial level* — packaging the DMC chips as
//! chiplets (MCM / 2.5D) — and explore the performance/cost trade-off of
//! chiplets-per-package. Demonstrates the meta-DSE capability existing
//! template-bound tools lack: the hierarchy itself is a search axis.
//!
//! ```sh
//! cargo run --release --example spatial_level_dse [-- --quick]
//! ```

use mldse::arch::MpmcParams;
use mldse::coordinator::Coordinator;
use mldse::cost::Packaging;
use mldse::hwir::mlc;

fn main() -> mldse::util::error::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let coord = Coordinator::standard();

    // Demonstrate the hierarchy change structurally first.
    let flat = mldse::arch::DmcParams::default().build();
    let deep = MpmcParams::paper(2, Packaging::Mcm).build();
    println!(
        "spatial hierarchy: flat DMC = {} levels; MPMC-DMC = {} levels",
        flat.root.depth(),
        deep.root.depth()
    );
    let route = deep.route(
        &mlc(&[&[0], &[0], &[0, 0]]),
        &mlc(&[&[5], &[1], &[7, 3]]),
    );
    println!(
        "cross-level route example (chiplet core -> far chiplet core): {} segments:",
        route.len()
    );
    for seg in &route {
        println!(
            "  via {:<10} {} -> {} ({} hops)",
            deep.point(seg.comm).name,
            seg.from,
            seg.to,
            seg.hops
        );
    }
    println!();

    // Then run the §7.4 experiments.
    for t in coord.run_experiment("fig10", quick)? {
        println!("{}", t.render());
    }

    println!(
        "Compare with the paper:\n\
         * temporal decode is DRAM-bound (high DRAM utilization, idle cores);\n\
         * spatial computing removes the DRAM bottleneck entirely;\n\
         * more chiplets/package trades board links for NoP links: faster\n\
           but costlier, with the MCM cost-performance optimum at 2."
    );
    Ok(())
}
