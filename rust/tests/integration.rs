//! End-to-end integration tests over the public API: spec text → hardware →
//! workload → mapping primitives → simulation → reports.

use mldse::arch::{DmcParams, GsmParams, MpmcParams};
use mldse::coordinator::Coordinator;
use mldse::cost::Packaging;
use mldse::eval::Registry;
use mldse::hwir::{mlc, Hardware};
use mldse::sim::{simulate, SimConfig};
use mldse::workloads::{dmc_decode_temporal, dmc_prefill, gsm_prefill, mpmc_decode_spatial, LlmConfig};

fn small_cfg() -> LlmConfig {
    LlmConfig {
        hidden: 512,
        heads: 8,
        ffn: 2048,
        layers: 4,
        elem_bytes: 2,
    }
}

/// Declarative spec text → operable hardware → simulation.
#[test]
fn spec_to_simulation_end_to_end() {
    let spec = r#"{
      "matrix": {
        "name": "board", "dims": [2],
        "comms": [{"name": "bnet", "topology": "ring",
                   "link_bandwidth": 16, "link_latency": 4}],
        "cells": [
          {"at": [0], "matrix": {
            "name": "chip", "dims": [2, 2],
            "comms": [{"name": "noc", "topology": "mesh",
                       "link_bandwidth": 32, "link_latency": 1}],
            "fill": {"point": {"name": "core", "kind": "compute",
                     "systolic": [16, 16], "vector_lanes": 64,
                     "lmem": {"capacity": 1048576, "bandwidth": 64,
                              "latency": 2}}}
          }},
          {"at": [1], "point": {"name": "dram", "kind": "dram",
           "capacity": 1073741824, "bandwidth": 256, "latency": 80}}
        ]
      }
    }"#;
    let hw = Hardware::build(mldse::hwir::parse_spec(spec).unwrap());
    assert_eq!(hw.points_of_kind("compute").len(), 4);
    assert_eq!(hw.root.depth(), 2);

    // roundtrip through the serializer
    let text = mldse::hwir::to_spec(&hw.root).to_pretty();
    let hw2 = Hardware::build(mldse::hwir::parse_spec(&text).unwrap());
    assert_eq!(hw2.num_points(), hw.num_points());

    // map a tiny graph across the spec-built hardware and simulate
    use mldse::mapping::MappingState;
    use mldse::taskgraph::{ComputeCost, OpClass, TaskGraph, TaskKind};
    let mut g = TaskGraph::new();
    let mut c = ComputeCost::zero(OpClass::MatMul);
    c.dims = [64, 64, 64];
    c.mac_flops = 2.0 * 64.0f64.powi(3);
    c.in_bytes = 16384;
    c.out_bytes = 8192;
    let t = g.add("mm", TaskKind::Compute(c));
    let x = g.add("xfer", TaskKind::Comm { bytes: 8192, hops: 0, route: None });
    let u = g.add("mm2", TaskKind::Compute(c));
    g.connect(t, x);
    g.connect(x, u);
    let mut st = MappingState::new(g);
    let c00 = hw.cell(&mlc(&[&[0], &[0, 0]])).unwrap();
    let c11 = hw.cell(&mlc(&[&[0], &[1, 1]])).unwrap();
    st.map_node(t, c00).unwrap();
    st.map_node(u, c11).unwrap();
    let segs = hw.route(&mlc(&[&[0], &[0, 0]]), &mlc(&[&[0], &[1, 1]]));
    st.map_edge(x, &segs).unwrap();
    let r = simulate(&hw, &st.graph, &st.mapping, &Registry::standard(), &SimConfig::default())
        .unwrap();
    assert!(r.makespan > 0.0);
    assert_eq!(r.unfinished, 0);
}

/// Table 3 "flexible spatial level": the same workload code runs on a
/// 2-level chip and on a 4-level board without changes.
#[test]
fn capability_flexible_spatial_levels() {
    let cfg = small_cfg();
    // 2 levels
    let flat = dmc_decode_temporal(&cfg, 128, 1, &DmcParams { grid: (2, 2), ..Default::default() });
    assert_eq!(flat.hw.root.depth(), 2);
    // 4 levels (board -> package -> chiplet -> core)
    let mut p = MpmcParams::paper(2, Packaging::Mcm);
    p.total_chiplets = 4;
    p.chiplet.grid = (2, 2);
    let deep = mpmc_decode_spatial(&cfg, 128, 1, &p);
    assert_eq!(deep.hw.root.depth(), 3);
    let evals = Registry::standard();
    for w in [&flat, &deep] {
        let r = simulate(&w.hw, &w.graph, &w.mapping, &evals, &SimConfig::default()).unwrap();
        assert_eq!(r.unfinished, 0, "{}", w.name);
    }
}

/// Table 3 "flexible organization": heterogeneous cells in one matrix —
/// two compute chiplets with different systolic arrays plus an IO die,
/// like the paper's Figure 3 package.
#[test]
fn capability_heterogeneous_package() {
    use mldse::hwir::{CommAttrs, ComputeAttrs, Coord, Element, MemoryAttrs, SpaceMatrix, SpacePoint, Topology};
    let mut pkg = SpaceMatrix::new("package", vec![3]);
    let mut big = SpaceMatrix::new("compute-big", vec![2]);
    for i in 0..2 {
        big.set(
            Coord::new(vec![i]),
            Element::Point(SpacePoint::compute(
                "core",
                ComputeAttrs::new((64, 64), 256).with_lmem(MemoryAttrs::new(1 << 20, 128.0, 1)),
            )),
        );
    }
    big.add_comm(SpacePoint::comm("noc", CommAttrs::new(Topology::Mesh, 32.0, 1)));
    let mut small = SpaceMatrix::new("compute-small", vec![4]);
    for i in 0..4 {
        small.set(
            Coord::new(vec![i]),
            Element::Point(SpacePoint::compute(
                "core",
                ComputeAttrs::new((16, 16), 64).with_lmem(MemoryAttrs::new(1 << 19, 64.0, 1)),
            )),
        );
    }
    small.add_comm(SpacePoint::comm("noc", CommAttrs::new(Topology::Ring, 16.0, 1)));
    pkg.set(Coord::new(vec![0]), Element::Matrix(big));
    pkg.set(Coord::new(vec![1]), Element::Matrix(small));
    pkg.set(
        Coord::new(vec![2]),
        Element::Point(SpacePoint::dram("io-die", MemoryAttrs::new(1 << 30, 256.0, 60))),
    );
    pkg.add_comm(SpacePoint::comm("nop", CommAttrs::new(Topology::Bus, 64.0, 4)));
    let hw = Hardware::build(pkg);
    assert_eq!(hw.points_of_kind("compute").len(), 6);
    // cross-chiplet route passes both NoCs and the NoP
    let segs = hw.route(&mlc(&[&[0], &[1]]), &mlc(&[&[1], &[3]]));
    let names: Vec<&str> = segs.iter().map(|s| hw.point(s.comm).name.as_str()).collect();
    assert_eq!(names, ["noc", "nop", "noc"]);
}

/// Table 3 "mixed granularity": a cluster mixing atomic GPUs with a
/// fine-grained chiplet model in the same matrix simulates fine.
#[test]
fn capability_mixed_granularity() {
    use mldse::hwir::{CommAttrs, ComputeAttrs, Coord, Element, MemoryAttrs, SpaceMatrix, SpacePoint, Topology};
    use mldse::mapping::Mapping;
    use mldse::taskgraph::{ComputeCost, OpClass, TaskGraph, TaskKind};

    let mut cluster = SpaceMatrix::new("cluster", vec![2]);
    // coarse: one atomic GPU
    cluster.set(
        Coord::new(vec![0]),
        Element::Point(SpacePoint::compute(
            "gpu",
            ComputeAttrs::new((395, 395), 4096).with_lmem(MemoryAttrs::new(40 << 30, 1555.0, 300)),
        )),
    );
    // fine: a 2x2-core accelerator modeled to core level
    let dmc = DmcParams { grid: (2, 2), with_dram: false, ..Default::default() };
    cluster.set(Coord::new(vec![1]), Element::Matrix(dmc.chip_matrix("accel")));
    cluster.add_comm(SpacePoint::comm(
        "fabric",
        CommAttrs::new(Topology::FullyConnected, 64.0, 100),
    ));
    let hw = Hardware::build(cluster);
    assert_eq!(hw.points_of_kind("compute").len(), 5);

    let mut g = TaskGraph::new();
    let mut big = ComputeCost::zero(OpClass::MatMul);
    big.mac_flops = 1e9;
    let on_gpu = g.add("gpu-op", TaskKind::Compute(big));
    let mut tiny = ComputeCost::zero(OpClass::MatMul);
    tiny.mac_flops = 1e6;
    tiny.dims = [64, 64, 64];
    let on_core = g.add("core-op", TaskKind::Compute(tiny));
    let x = g.add("x", TaskKind::Comm { bytes: 1 << 20, hops: 0, route: None });
    g.connect(on_gpu, x);
    g.connect(x, on_core);
    let mut m = Mapping::new();
    m.map(on_gpu, hw.cell(&mlc(&[&[0]])).unwrap());
    m.map(on_core, hw.cell(&mlc(&[&[1], &[1, 1]])).unwrap());
    m.map(x, hw.comm(&mlc(&[]), 0).unwrap());
    let r = simulate(&hw, &g, &m, &Registry::standard(), &SimConfig::default()).unwrap();
    assert_eq!(r.unfinished, 0);
    assert!(r.timings[&on_core].1 > r.timings[&on_gpu].1);
}

/// Failure injection: bad workloads fail loudly, not silently.
#[test]
fn failure_injection() {
    let cfg = small_cfg();
    let w = dmc_prefill(&cfg, 128, &DmcParams { grid: (2, 2), ..Default::default() });
    let evals = Registry::standard();

    // zero iterations rejected
    let bad = SimConfig { iterations: 0, ..Default::default() };
    assert!(simulate(&w.hw, &w.graph, &w.mapping, &evals, &bad).is_err());

    // event cap enforced
    let capped = SimConfig { max_events: 3, ..Default::default() };
    assert!(simulate(&w.hw, &w.graph, &w.mapping, &evals, &capped).is_err());

    // unmapping an enabled task is caught
    let mut broken = mldse::mapping::Mapping::new();
    for (t, p) in w.mapping.mapped_tasks() {
        broken.map(t, p);
    }
    let victim = w.graph.iter().find(|t| t.enabled).unwrap().id;
    broken.unmap(victim);
    assert!(simulate(&w.hw, &w.graph, &broken, &evals, &SimConfig::default()).is_err());

    // mapping validation reports the same problem
    assert!(!broken.validate(&w.graph, &w.hw).is_empty());
}

/// The three simulators stay consistent on a real workload: exact engine
/// and Algorithm 1 agree; the naive baseline disagrees under contention.
#[test]
fn schedulers_cross_validate_on_real_workload() {
    let cfg = small_cfg();
    let params = DmcParams {
        grid: (2, 2),
        noc_bandwidth: 2.0,       // heavy NoC contention
        dram_bandwidth: 64.0,     // narrow DRAM channel
        lmem_capacity: 1 << 19,   // force weight streaming -> DRAM flows
        ..Default::default()
    };
    let w = dmc_prefill(&cfg, 128, &params);
    let evals = Registry::standard();
    let exact = simulate(&w.hw, &w.graph, &w.mapping, &evals, &SimConfig::default()).unwrap();
    let alg1 = mldse::sim::simulate_consistent(&w.hw, &w.graph, &w.mapping, &evals).unwrap();
    assert!(
        (exact.makespan - alg1.makespan).abs() / exact.makespan < 1e-9,
        "exact {} vs alg1 {}",
        exact.makespan,
        alg1.makespan
    );
    // the naive baseline diverges under contention (direction depends on
    // how its topo-order traversal interleaves with full-bandwidth comm)
    let naive = mldse::sim::simulate_naive(&w.hw, &w.graph, &w.mapping, &evals).unwrap();
    assert!(exact.truncations > 0, "workload should exhibit contention");
    let rel = (naive.makespan - exact.makespan).abs() / exact.makespan;
    assert!(rel > 1e-3, "naive should diverge: {} vs {}", naive.makespan, exact.makespan);
}

/// Energy accounting: streaming architectures burn DRAM energy; on-chip
/// (spatial) execution doesn't.
#[test]
fn energy_accounting_tracks_dram_traffic() {
    let cfg = small_cfg();
    let temporal = dmc_decode_temporal(&cfg, 256, 1, &DmcParams { grid: (2, 2), ..Default::default() });
    let evals = Registry::standard();
    let r = simulate(&temporal.hw, &temporal.graph, &temporal.mapping, &evals, &SimConfig::default())
        .unwrap();
    let dram = temporal.hw.points_of_kind("dram")[0];
    let dram_e = r.point_energy.get(&dram).copied().unwrap_or(0.0);
    assert!(dram_e > 0.0, "DRAM energy must be accounted");
    assert!(r.total_energy() > dram_e);
    assert!(r.avg_power_w(1.0) > 0.0);
}

/// GSM vs DMC at full scale through the coordinator (the §7.3.3 headline).
#[test]
fn dmc_beats_gsm_at_comparable_area() {
    let coord = Coordinator::standard();
    let cfg = LlmConfig::gpt3_6_7b();
    let seq = 512; // reduced for test runtime
    let dmc = dmc_prefill(&cfg, seq, &DmcParams::table2(2).unwrap());
    let gsm = gsm_prefill(&cfg, seq, &GsmParams::table2(2).unwrap());
    let rd = coord.simulate(&dmc, &SimConfig::default()).unwrap();
    let rg = coord.simulate(&gsm, &SimConfig::default()).unwrap();
    assert!(
        rd.makespan < rg.makespan,
        "DMC {} vs GSM {}",
        rd.makespan,
        rg.makespan
    );
}
