//! Bench E13 (§7.2 speed claim) plus the simulator-throughput trajectory.
//!
//! Three tiers, all recorded into a machine-readable `BENCH_sim.json` at
//! the repo root (uploaded as a CI artifact) so the trajectory is tracked
//! PR over PR:
//!
//! 1. **configs** — 240 DMC hardware configurations on the GPT3-6.7B
//!    prefill layer (paper: 240 configurations in 76 s);
//! 2. **prefill** — raw engine event throughput on one large workload;
//! 3. **contended NoC** — a mesh-NoC flow storm with mixed routed and
//!    routeless transfers, run under both the incremental contention
//!    tracker and the legacy full per-event recompute
//!    (`SimConfig::incremental = false`). The reported speedup is the
//!    headline number for the incremental-contention overhaul.

#[path = "common/mod.rs"]
mod common;

use mldse::arch::DmcParams;
use mldse::dse::experiments::{sim_speed, Ctx};
use mldse::eval::Registry;
use mldse::sim::{simulate, SimConfig};
use mldse::util::json::{Json, JsonObj};
use mldse::workloads::{contended_noc, dmc_prefill, LlmConfig};

fn main() {
    let quick = common::quick();
    let ctx = if quick { Ctx::quick() } else { Ctx::standard() };
    let mut out = JsonObj::new();
    out.insert("bench", "sim_speed".into());
    out.insert("quick", quick.into());

    // --- headline: 240 configurations ---
    let (table, secs) = sim_speed(&ctx);
    println!("{}", table.render());
    println!(
        "[bench] sim_speed: 240 configs in {secs:.2}s ({:.1} configs/s; paper: 240 in 76s)",
        240.0 / secs
    );
    out.insert("configs_240_wall_s", secs.into());
    out.insert("configs_per_s", (240.0 / secs).into());

    // --- raw engine throughput on one workload ---
    let cfg = if quick {
        LlmConfig { hidden: 512, heads: 8, ffn: 2048, layers: 8, elem_bytes: 2 }
    } else {
        LlmConfig::gpt3_6_7b()
    };
    let seq = if quick { 256 } else { 2048 };
    let params = DmcParams::table2(2).expect("config in 1..=4");
    let w = dmc_prefill(&cfg, seq, &params);
    let evals = Registry::standard();
    let mut completed = 0u64;
    let median = common::bench("single prefill simulation", 5, || {
        let r = simulate(&w.hw, &w.graph, &w.mapping, &evals, &SimConfig::default()).unwrap();
        completed = r.completed;
    });
    println!(
        "[bench] engine throughput: {:.0} task-events/s ({} tasks per sim)",
        completed as f64 / median,
        completed
    );
    out.insert("prefill_wall_s", median.into());
    out.insert("prefill_events_per_s", (completed as f64 / median).into());
    out.insert("prefill_tasks", completed.into());

    // --- contended NoC: incremental vs full per-event recompute ---
    let (flows, grid, iters) = if quick {
        (96usize, (4usize, 4usize), 2u32)
    } else {
        (384, (8, 8), 4)
    };
    let wc = contended_noc(flows, grid, 0xBE9C);
    let base = SimConfig { iterations: iters, ..Default::default() };
    let mut done_incr = 0u64;
    let incr_s = common::bench("contended NoC (incremental)", 5, || {
        let r = simulate(&wc.hw, &wc.graph, &wc.mapping, &evals, &base).unwrap();
        assert_eq!(r.unfinished, 0);
        done_incr = r.completed;
    });
    let full_cfg = SimConfig { incremental: false, ..base };
    let mut done_full = 0u64;
    let full_s = common::bench("contended NoC (full recompute)", 5, || {
        let r = simulate(&wc.hw, &wc.graph, &wc.mapping, &evals, &full_cfg).unwrap();
        done_full = r.completed;
    });
    assert_eq!(done_incr, done_full, "paths must complete the same work");
    let ev_incr = done_incr as f64 / incr_s;
    let ev_full = done_full as f64 / full_s;
    println!(
        "[bench] contended NoC ({flows} flows, {}x{} mesh, {iters} iters): \
         {ev_incr:.0} ev/s incremental vs {ev_full:.0} ev/s full recompute ({:.2}x)",
        grid.0,
        grid.1,
        ev_incr / ev_full
    );
    out.insert("contended_flows", flows.into());
    out.insert("contended_events_per_s_incremental", ev_incr.into());
    out.insert("contended_events_per_s_full", ev_full.into());
    out.insert("contended_speedup", (ev_incr / ev_full).into());

    let doc = Json::Obj(out).to_pretty();
    std::fs::write("BENCH_sim.json", &doc).expect("write BENCH_sim.json");
    println!("[bench] wrote BENCH_sim.json");
}
