//! End-to-end tests of `mldse bench run|compare|list` against the real
//! binary (`CARGO_BIN_EXE_mldse`), proving the ISSUE-level acceptance
//! criteria:
//!
//! * two `bench run`s over the same scenarios produce identical summaries
//!   modulo the `"timing"` blocks — fingerprints byte-equal;
//! * injecting a synthetic >10% throughput loss makes `bench compare`
//!   exit non-zero with a per-scenario diagnosis;
//! * mutating a single result fingerprint makes `bench compare` exit
//!   non-zero even when throughput *improves*;
//! * a self-compare passes, the shipped bootstrap baseline passes with a
//!   refresh notice, and scenario validation errors surface through the
//!   CLI naming the offending field and file.

use std::path::{Path, PathBuf};
use std::process::{Command, Output};

use mldse::bench::summary::Timing;
use mldse::bench::Summary;

fn mldse() -> Command {
    let mut cmd = Command::new(env!("CARGO_BIN_EXE_mldse"));
    cmd.env_remove("MLDSE_WORKERS");
    cmd.env_remove("MLDSE_BENCH_QUICK");
    cmd
}

/// Per-test scratch directory (the test binary may run tests in
/// parallel, so names carry the test's own tag).
fn scratch(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("mldse-bench-{}-{tag}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("create scratch dir");
    dir
}

/// A tiny scenario set: the 4-core mapping placement demo, cheap enough
/// for debug-build end-to-end runs.
fn write_scenarios(dir: &Path) -> PathBuf {
    let scenarios = dir.join("scenarios");
    std::fs::create_dir_all(&scenarios).expect("create scenario dir");
    std::fs::write(
        scenarios.join("mapping_small.json"),
        r#"{
  "name": "mapping-small",
  "family": "mapping",
  "explorer": "anneal",
  "budget": 6,
  "quick_budget": 3,
  "seeds": [3, 4],
  "workers": 2,
  "metrics_every": 2
}
"#,
    )
    .expect("write scenario");
    scenarios
}

fn run_ok(cmd: &mut Command) -> Output {
    let out = cmd.output().expect("run mldse");
    assert!(
        out.status.success(),
        "expected success\nstdout: {}\nstderr: {}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    out
}

fn run_fail(cmd: &mut Command) -> Output {
    let out = cmd.output().expect("run mldse");
    assert!(
        !out.status.success(),
        "expected failure\nstdout: {}\nstderr: {}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    out
}

fn bench_run(scenarios: &Path, out_file: &Path) {
    run_ok(mldse().args([
        "bench",
        "run",
        "--scenarios",
        scenarios.to_str().unwrap(),
        "--out",
        out_file.to_str().unwrap(),
    ]));
}

/// The summary with every timing block replaced by a fixed value — what
/// "identical modulo timing" means, byte-for-byte: serializing the
/// normalized summaries yields equal JSONL documents, fingerprints
/// included.
fn normalized(path: &Path) -> String {
    let mut s = Summary::read(path).expect("read summary");
    for rec in &mut s.scenarios {
        rec.timing = Timing {
            wall_secs: 0.0,
            evals_per_sec: 0.0,
            setup_ms: 0.0,
            batch_ms_p50: 0.0,
            batch_ms_p95: 0.0,
            batch_ms_max: 0.0,
        };
    }
    s.to_jsonl()
}

#[test]
fn run_twice_is_identical_modulo_timing() {
    let dir = scratch("determinism");
    let scenarios = write_scenarios(&dir);
    let a = dir.join("a.jsonl");
    let b = dir.join("b.jsonl");
    bench_run(&scenarios, &a);
    bench_run(&scenarios, &b);

    assert_eq!(
        normalized(&a),
        normalized(&b),
        "two bench runs diverged outside the timing fields"
    );
    // fingerprints byte-equal in the raw files too
    let fp_line = |p: &Path| {
        let text = std::fs::read_to_string(p).unwrap();
        let s = Summary::parse(&text, "t").unwrap();
        (s.scenarios[0].fingerprint, s.scenarios[0].run_fingerprints.clone())
    };
    assert_eq!(fp_line(&a), fp_line(&b));

    // and the timing fields are real measurements, not zeros
    let s = Summary::read(&a).unwrap();
    assert!(s.scenarios[0].timing.wall_secs > 0.0);
    assert!(s.scenarios[0].timing.evals_per_sec > 0.0);
    assert_eq!(s.scenarios[0].seeds, vec![3, 4]);
    assert_eq!(s.scenarios[0].budget, 6, "non-quick run uses the full budget");
}

#[test]
fn self_compare_passes() {
    let dir = scratch("selfcmp");
    let scenarios = write_scenarios(&dir);
    let a = dir.join("a.jsonl");
    bench_run(&scenarios, &a);
    let out = run_ok(mldse().args([
        "bench",
        "compare",
        a.to_str().unwrap(),
        a.to_str().unwrap(),
    ]));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("PASS mapping-small"), "{stdout}");
    assert!(stdout.contains("bench compare: PASS"), "{stdout}");
}

#[test]
fn synthetic_throughput_loss_fails_the_gate() {
    let dir = scratch("tput");
    let scenarios = write_scenarios(&dir);
    let base = dir.join("base.jsonl");
    bench_run(&scenarios, &base);

    // inject a 20% throughput loss (> the 10% default threshold)
    let mut cur = Summary::read(&base).unwrap();
    cur.scenarios[0].timing.evals_per_sec *= 0.8;
    let cur_path = dir.join("cur.jsonl");
    cur.write(&cur_path).unwrap();

    let out = run_fail(mldse().args([
        "bench",
        "compare",
        base.to_str().unwrap(),
        cur_path.to_str().unwrap(),
    ]));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("FAIL mapping-small"), "{stdout}");
    assert!(stdout.contains("throughput regressed 20.0%"), "{stdout}");

    // a looser threshold lets the same loss through
    run_ok(mldse().args([
        "bench",
        "compare",
        base.to_str().unwrap(),
        cur_path.to_str().unwrap(),
        "--threshold",
        "25",
    ]));
}

#[test]
fn fingerprint_break_fails_even_with_faster_run() {
    let dir = scratch("fp");
    let scenarios = write_scenarios(&dir);
    let base = dir.join("base.jsonl");
    bench_run(&scenarios, &base);

    let mut cur = Summary::read(&base).unwrap();
    cur.scenarios[0].fingerprint ^= 1;
    cur.scenarios[0].run_fingerprints[1] ^= 1;
    // throughput *improves*: the fingerprint check must still win
    cur.scenarios[0].timing.evals_per_sec *= 2.0;
    let cur_path = dir.join("cur.jsonl");
    cur.write(&cur_path).unwrap();

    let out = run_fail(mldse().args([
        "bench",
        "compare",
        base.to_str().unwrap(),
        cur_path.to_str().unwrap(),
    ]));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("FAIL mapping-small"), "{stdout}");
    assert!(stdout.contains("result fingerprint broke"), "{stdout}");
    assert!(stdout.contains("seed 4"), "diagnosis localizes the seed: {stdout}");
}

#[test]
fn quick_env_var_shrinks_budgets() {
    let dir = scratch("quick");
    let scenarios = write_scenarios(&dir);
    let out_file = dir.join("quick.jsonl");
    run_ok(
        mldse()
            .args([
                "bench",
                "run",
                "--scenarios",
                scenarios.to_str().unwrap(),
                "--out",
                out_file.to_str().unwrap(),
            ])
            .env("MLDSE_BENCH_QUICK", "1"),
    );
    let s = Summary::read(&out_file).unwrap();
    assert!(s.env.quick);
    assert_eq!(s.scenarios[0].budget, 3, "quick_budget substituted");
}

#[test]
fn bootstrap_baseline_passes_with_refresh_notice() {
    let dir = scratch("bootstrap");
    let scenarios = write_scenarios(&dir);
    let cur = dir.join("cur.jsonl");
    bench_run(&scenarios, &cur);
    let baseline = concat!(env!("CARGO_MANIFEST_DIR"), "/benches/baselines/quick.jsonl");
    let out = run_ok(mldse().args(["bench", "compare", baseline, cur.to_str().unwrap()]));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("bootstrap placeholder"), "{stdout}");
    assert!(stdout.contains("bench run --quick"), "{stdout}");
}

#[test]
fn shipped_scenarios_parse_and_list() {
    let scenarios = concat!(env!("CARGO_MANIFEST_DIR"), "/benches/scenarios");
    let out = run_ok(mldse().args(["bench", "list", "--scenarios", scenarios]));
    let stdout = String::from_utf8_lossy(&out.stdout);
    for name in [
        "dmc-prefill-anneal",
        "gsm-prefill-random",
        "packaging-grid-batched",
        "mapping-hill-setup-reuse",
        "three-tier-anneal-tiered",
    ] {
        assert!(stdout.contains(name), "missing scenario '{name}':\n{stdout}");
    }
}

#[test]
fn scenario_validation_errors_surface_through_the_cli() {
    let dir = scratch("badscenario");
    let bad = dir.join("bad.json");
    std::fs::write(
        &bad,
        r#"{"name": "x", "family": "dcm-prefill", "budget": 8}"#,
    )
    .unwrap();
    let out = run_fail(mldse().args(["bench", "run", "--scenarios", bad.to_str().unwrap()]));
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("bad.json"), "{stderr}");
    assert!(stderr.contains("\"family\""), "{stderr}");
    assert!(stderr.contains("unknown workload family 'dcm-prefill'"), "{stderr}");
}

#[test]
fn duplicate_scenario_names_error_with_both_paths() {
    // The summary format and the compare gate key on the scenario name, so
    // two files claiming the same name must fail fast — citing both files,
    // not just the second one.
    let dir = scratch("dupname");
    let scenarios = dir.join("scenarios");
    std::fs::create_dir_all(&scenarios).unwrap();
    let body = r#"{
  "name": "mapping-small",
  "family": "mapping",
  "explorer": "anneal",
  "budget": 6,
  "quick_budget": 3,
  "seeds": [3],
  "workers": 2
}
"#;
    std::fs::write(scenarios.join("first.json"), body).unwrap();
    std::fs::write(scenarios.join("second.json"), body).unwrap();
    let out = run_fail(mldse().args([
        "bench",
        "run",
        "--scenarios",
        scenarios.to_str().unwrap(),
        "--out",
        dir.join("out.jsonl").to_str().unwrap(),
    ]));
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(
        stderr.contains("duplicate scenario name 'mapping-small'"),
        "{stderr}"
    );
    assert!(stderr.contains("first.json"), "{stderr}");
    assert!(stderr.contains("second.json"), "{stderr}");
}

#[test]
fn preflight_rejects_a_broken_scenario_set_before_any_run() {
    // A scenario that parses but fails static checks (custom family whose
    // space file is missing) aborts the whole set with a named diagnostic
    // before the first scenario spends its budget.
    let dir = scratch("preflight");
    let scenarios = write_scenarios(&dir);
    std::fs::write(
        scenarios.join("broken.json"),
        r#"{
  "name": "broken-custom",
  "family": "custom",
  "space": "does/not/exist.json",
  "explorer": "anneal",
  "budget": 6,
  "seeds": [1],
  "workers": 2
}
"#,
    )
    .unwrap();
    let out = run_fail(mldse().args([
        "bench",
        "run",
        "--scenarios",
        scenarios.to_str().unwrap(),
        "--out",
        dir.join("out.jsonl").to_str().unwrap(),
    ]));
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("MLDSE-E052"), "{stderr}");
    assert!(stderr.contains("broken-custom"), "{stderr}");
    assert!(stderr.contains("scenario set failed static checks"), "{stderr}");
    // no summary written: the failure precedes the first run
    assert!(!dir.join("out.jsonl").exists(), "summary must not be written");
}

#[test]
fn compare_usage_and_unknown_subcommand_are_errors() {
    let out = run_fail(mldse().args(["bench", "compare", "only-one.jsonl"]));
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("usage"), "{stderr}");

    let out = run_fail(mldse().args(["bench", "frobnicate"]));
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("unknown subcommand 'frobnicate'"), "{stderr}");

    let out = run_fail(mldse().args(["bench"]));
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("subcommand is required"), "{stderr}");
}
