//! Bench: exploration-engine throughput (evals/sec), recorded into a
//! machine-readable `BENCH_explore.json` at the repo root (uploaded as a
//! CI artifact, mirroring `BENCH_sim.json`) so the exploration-throughput
//! trajectory is tracked PR over PR.
//!
//! Three sections:
//!
//! 1. **presets** — evals/sec for the four explorers on the DMC
//!    hardware-parameter preset (the whole-candidate-topology case);
//! 2. **SA mapping tier** — the headline number for the throughput
//!    overhaul: a simulated-annealing placement search run through the new
//!    engine (persistent worker pool + topology-keyed setup reuse +
//!    arena-reusing sim sessions) versus the pre-overhaul batched engine
//!    (`streaming = false`, `setup_reuse = false`: per-batch scoped
//!    threads, fresh hardware/route-table/arenas per candidate);
//! 3. **hill-climb mapping tier** — same comparison with batched neighbor
//!    proposals, exercising the streaming pool with multi-candidate
//!    batches.
//!
//! Run with `cargo bench --bench explore_speed` (MLDSE_BENCH_QUICK=1 for
//! the smoke-sized configuration).

#[path = "common/mod.rs"]
mod common;

use mldse::dse::explore::{
    explore, explorer_by_name, placement_demo, preset, AnnealExplorer, Explorer, ExploreOpts,
    HillClimbExplorer, Makespan, Objective,
};
use mldse::eval::Registry;
use mldse::util::json::{Json, JsonObj};

/// Median seconds for one exploration run under `opts`.
fn time_explore(
    name: &str,
    space: &dyn mldse::dse::explore::DesignSpace,
    objectives: &[Box<dyn Objective>],
    explorer: &dyn Explorer,
    registry: &Registry,
    opts: &ExploreOpts,
    reps: usize,
) -> (f64, mldse::dse::explore::ExplorationReport) {
    let mut last = None;
    let median = common::bench(name, reps, || {
        last = Some(explore(space, objectives, explorer, registry, opts).expect("explore"));
    });
    (median, last.expect("at least one run"))
}

fn main() {
    let quick = common::quick();
    let registry = Registry::standard();
    let mut out = JsonObj::new();
    out.insert("bench", "explore_speed".into());
    out.insert("quick", quick.into());

    // --- 1. explorer throughput on the DMC hardware-parameter preset ---
    let preset_name = if quick { "dmc-quick" } else { "dmc" };
    let budget = if quick { 24 } else { 200 };
    let mut presets = JsonObj::new();
    for name in ["grid", "random", "hill", "anneal"] {
        let (space, objectives): (_, Vec<Box<dyn Objective>>) =
            preset(preset_name).expect("preset");
        let explorer = explorer_by_name(name, 0xD5E).expect("explorer");
        let opts = ExploreOpts {
            budget,
            ..Default::default()
        };
        let report = explore(
            space.as_ref(),
            &objectives,
            explorer.as_ref(),
            &registry,
            &opts,
        )
        .expect("exploration");
        println!("{}", report.summary_table().render());
        println!(
            "[bench] explore {preset_name}/{name}: {} evals, {} sims, {:.2} evals/s",
            report.evals.len(),
            report.sim_calls,
            report.evals_per_sec()
        );
        presets.insert(
            format!("{preset_name}/{name}"),
            report.evals_per_sec().into(),
        );
    }
    out.insert("presets", Json::Obj(presets));

    // --- 2. SA mapping tier: new engine vs pre-overhaul batched engine ---
    // The placement space shares one topology across every candidate, so
    // the setup cache builds hardware/route-table once for the whole
    // search and the annealer's one-candidate proposals ride the
    // arena-reusing inline path instead of a spawn-join barrier.
    // A hardware-heavy placement problem: the legacy path clones the
    // 36/64-core chip and rebuilds routes + arenas per candidate, while
    // the new path rebinds a small mapping against one shared setup.
    let (grid, tasks, sa_budget, reps) = if quick {
        ((6usize, 6usize), 12usize, 300usize, 3usize)
    } else {
        ((8, 8), 24, 2000, 5)
    };
    let space = placement_demo("map-sa-bench", grid, tasks);
    let objectives: Vec<Box<dyn Objective>> = vec![Box::new(Makespan)];
    let annealer = AnnealExplorer {
        seed: 0xD5E,
        init_temp: 0.1,
        tiered: false,
    };
    let new_opts = ExploreOpts {
        budget: sa_budget,
        ..Default::default()
    };
    let legacy_opts = ExploreOpts {
        budget: sa_budget,
        streaming: false,
        setup_reuse: false,
        ..Default::default()
    };
    let (new_s, new_report) = time_explore(
        "SA mapping (streaming + setup reuse)",
        &space,
        &objectives,
        &annealer,
        &registry,
        &new_opts,
        reps,
    );
    let (legacy_s, legacy_report) = time_explore(
        "SA mapping (batched legacy)",
        &space,
        &objectives,
        &annealer,
        &registry,
        &legacy_opts,
        reps,
    );
    // both paths must agree bit-exactly (the determinism suite pins the
    // full report; this is the bench-side sanity check)
    assert_eq!(new_report.evals.len(), legacy_report.evals.len());
    assert_eq!(
        new_report.best().map(|e| e.objectives[0].to_bits()),
        legacy_report.best().map(|e| e.objectives[0].to_bits()),
        "streaming and batched paths diverged"
    );
    let sa_new = sa_budget as f64 / new_s;
    let sa_legacy = sa_budget as f64 / legacy_s;
    println!(
        "[bench] SA mapping tier ({}x{} grid, {tasks} tasks, {sa_budget} evals): \
         {sa_new:.0} evals/s new vs {sa_legacy:.0} evals/s legacy ({:.2}x), \
         setup cache hit rate {:.3}",
        grid.0,
        grid.1,
        sa_new / sa_legacy,
        new_report.setup_hit_rate()
    );
    let mut sa = JsonObj::new();
    sa.insert("budget", (sa_budget as u64).into());
    sa.insert("evals_per_sec_streaming", sa_new.into());
    sa.insert("evals_per_sec_batched_legacy", sa_legacy.into());
    sa.insert("streaming_vs_batched_speedup", (sa_new / sa_legacy).into());
    sa.insert("setup_cache_hit_rate", new_report.setup_hit_rate().into());
    sa.insert("setup_builds", (new_report.setup_builds as u64).into());
    sa.insert("sim_calls", (new_report.sim_calls as u64).into());
    out.insert("sa_mapping", Json::Obj(sa));

    // --- 3. hill-climb mapping tier (multi-candidate neighbor batches) ---
    let hc_budget = if quick { 200 } else { 1200 };
    let climber = HillClimbExplorer {
        seed: 0xD5E,
        from_initial: true,
        restarts: true,
    };
    let hc_new = ExploreOpts {
        budget: hc_budget,
        ..Default::default()
    };
    let hc_legacy = ExploreOpts {
        budget: hc_budget,
        streaming: false,
        setup_reuse: false,
        ..Default::default()
    };
    let (hn_s, _) = time_explore(
        "hill mapping (streaming + setup reuse)",
        &space,
        &objectives,
        &climber,
        &registry,
        &hc_new,
        reps,
    );
    let (hl_s, _) = time_explore(
        "hill mapping (batched legacy)",
        &space,
        &objectives,
        &climber,
        &registry,
        &hc_legacy,
        reps,
    );
    let hc_speedup = (hc_budget as f64 / hn_s) / (hc_budget as f64 / hl_s);
    println!(
        "[bench] hill mapping tier: {:.0} evals/s new vs {:.0} evals/s legacy ({hc_speedup:.2}x)",
        hc_budget as f64 / hn_s,
        hc_budget as f64 / hl_s,
    );
    let mut hc = JsonObj::new();
    hc.insert("budget", (hc_budget as u64).into());
    hc.insert("evals_per_sec_streaming", (hc_budget as f64 / hn_s).into());
    hc.insert(
        "evals_per_sec_batched_legacy",
        (hc_budget as f64 / hl_s).into(),
    );
    hc.insert("streaming_vs_batched_speedup", hc_speedup.into());
    out.insert("hill_mapping", Json::Obj(hc));

    // --- 4. joint three-tier search (composed NestedSpace) ---
    // The tier-aware annealer over arch × hw-param × mapping: throughput
    // plus how hard the per-outer-candidate EvalPlan cache works.
    let tt_budget = if quick { 24 } else { 120 };
    let tt_space = mldse::dse::explore::three_tier("three-tier-bench", quick)
        .expect("three-tier space");
    let tt_objectives: Vec<Box<dyn Objective>> = vec![Box::new(Makespan)];
    let tt_annealer = AnnealExplorer {
        seed: 0xD5E,
        init_temp: 0.1,
        tiered: true,
    };
    let tt_opts = ExploreOpts {
        budget: tt_budget,
        ..Default::default()
    };
    let (tt_s, tt_report) = time_explore(
        "three-tier joint search (tiered SA)",
        &tt_space,
        &tt_objectives,
        &tt_annealer,
        &registry,
        &tt_opts,
        reps.min(3),
    );
    println!(
        "[bench] three-tier joint search: {:.1} evals/s, {} outer topologies built \
         for {} sims (setup hit rate {:.3})",
        tt_report.evals.len() as f64 / tt_s,
        tt_report.setup_builds,
        tt_report.sim_calls,
        tt_report.setup_hit_rate()
    );
    let mut tt = JsonObj::new();
    tt.insert("budget", (tt_budget as u64).into());
    tt.insert(
        "evals_per_sec",
        (tt_report.evals.len() as f64 / tt_s).into(),
    );
    tt.insert("setup_builds", (tt_report.setup_builds as u64).into());
    tt.insert("sim_calls", (tt_report.sim_calls as u64).into());
    tt.insert("setup_cache_hit_rate", tt_report.setup_hit_rate().into());
    out.insert("three_tier", Json::Obj(tt));

    let doc = Json::Obj(out).to_pretty();
    std::fs::write("BENCH_explore.json", &doc).expect("write BENCH_explore.json");
    println!("[bench] wrote BENCH_explore.json");
}
