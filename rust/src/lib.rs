//! # MLDSE — Multi-Level Design Space Explorer
//!
//! A meta-DSE infrastructure for multi-level hardware, reproducing
//! *"MLDSE: Scaling Design Space Exploration Infrastructure for Multi-Level
//! Hardware"* (CS.AR 2025).
//!
//! The crate is organised around the paper's three pillars:
//!
//! * **Modeling** — [`hwir`]: a recursive hardware IR (`SpaceMatrix` /
//!   `SpacePoint`) that can describe arbitrary multi-level hardware with
//!   mixed granularity, plus a hardware builder and topology models.
//! * **Mapping** — [`taskgraph`] + [`mapping`]: a spatiotemporal mapping IR
//!   over tensor-granularity task graphs and the paper's sixteen mapping
//!   primitives (Table 1), including cross-level communication decomposition
//!   and hierarchical synchronization with multi-level space-time
//!   coordinates.
//! * **Simulation** — [`sim`]: JIT-generated task-level event-driven
//!   simulation with the hardware-consistent scheduler (Algorithm 1) that
//!   resolves general task-level resource contention, plus pluggable
//!   per-`SpacePoint` evaluators ([`eval`]) including a PJRT-backed one
//!   executing the AOT-compiled JAX/Pallas evaluator ([`runtime`]).
//!
//! On top sit the architecture templates ([`arch`]), cost models ([`cost`]),
//! LLM workload generators ([`workloads`]) and the three-tier DSE engine
//! ([`dse`]) orchestrated by the [`coordinator`], with the exploration
//! stack exposed as a resumable job daemon by [`serve`] and held to its
//! throughput and bit-determinism claims by the [`bench`] scenario runner
//! and regression gate. Every declarative artifact the stack consumes —
//! specs, mapping programs, spaces, scenarios — is statically checkable
//! via [`analyze`] (`mldse check`), which also backs the explore/serve/
//! bench pre-flights.

pub mod util;
pub mod ml;
pub mod hwir;
pub mod taskgraph;
pub mod mapping;
pub mod analyze;
pub mod eval;
pub mod sim;
pub mod arch;
pub mod cost;
pub mod workloads;
pub mod dse;
pub mod bench;
pub mod runtime;
pub mod coordinator;
pub mod serve;
