//! Spatial coordinates.
//!
//! * [`Coord`] — a position *within one spatial level*, n-dimensional
//!   (the paper's "(a, b, c)" tuples). The dimensionality must match the
//!   owning `SpaceMatrix`.
//! * [`MlCoord`] — a *multi-level* coordinate, the chain of per-level
//!   coordinates from the outermost level inwards (the paper's
//!   `((a,b,c) → (d,e))` notation, Figure 2/3).

use std::fmt;

/// Position inside a single spatial level (row-major semantics).
#[derive(Debug, Clone, PartialEq, Eq, Hash, PartialOrd, Ord, Default)]
pub struct Coord(pub Vec<u32>);

impl Coord {
    pub fn new(dims: impl Into<Vec<u32>>) -> Self {
        Coord(dims.into())
    }

    /// Number of dimensions.
    pub fn ndim(&self) -> usize {
        self.0.len()
    }

    /// Linearize against a shape (row-major). Returns `None` when the
    /// dimensionality mismatches or any component is out of bounds.
    pub fn linearize(&self, shape: &[usize]) -> Option<usize> {
        if self.0.len() != shape.len() {
            return None;
        }
        let mut idx = 0usize;
        for (c, s) in self.0.iter().zip(shape) {
            if *c as usize >= *s {
                return None;
            }
            idx = idx * s + *c as usize;
        }
        Some(idx)
    }

    /// Inverse of [`Coord::linearize`].
    pub fn from_linear(mut idx: usize, shape: &[usize]) -> Option<Coord> {
        let total: usize = shape.iter().product();
        if idx >= total.max(1) {
            return None;
        }
        let mut out = vec![0u32; shape.len()];
        for (slot, s) in out.iter_mut().zip(shape).rev() {
            *slot = (idx % s) as u32;
            idx /= s;
        }
        Some(Coord(out))
    }

    /// Manhattan distance between two coordinates of equal dimensionality.
    pub fn manhattan(&self, other: &Coord) -> u64 {
        assert_eq!(self.ndim(), other.ndim(), "dimension mismatch");
        self.0
            .iter()
            .zip(&other.0)
            .map(|(a, b)| (*a as i64 - *b as i64).unsigned_abs())
            .sum()
    }

    /// Manhattan distance with per-dimension wraparound (torus topologies).
    pub fn torus_distance(&self, other: &Coord, shape: &[usize]) -> u64 {
        assert_eq!(self.ndim(), other.ndim(), "dimension mismatch");
        assert_eq!(self.ndim(), shape.len(), "shape mismatch");
        self.0
            .iter()
            .zip(&other.0)
            .zip(shape)
            .map(|((a, b), s)| {
                let d = (*a as i64 - *b as i64).unsigned_abs();
                d.min(*s as u64 - d)
            })
            .sum()
    }
}

impl fmt::Display for Coord {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "(")?;
        for (i, c) in self.0.iter().enumerate() {
            if i > 0 {
                write!(f, ",")?;
            }
            write!(f, "{}", c)?;
        }
        write!(f, ")")
    }
}

impl From<Vec<u32>> for Coord {
    fn from(v: Vec<u32>) -> Self {
        Coord(v)
    }
}

impl From<&[u32]> for Coord {
    fn from(v: &[u32]) -> Self {
        Coord(v.to_vec())
    }
}

/// Multi-level coordinate: per-level positions, outermost first.
///
/// The empty `MlCoord` addresses the root `SpaceMatrix` itself.
#[derive(Debug, Clone, PartialEq, Eq, Hash, PartialOrd, Ord, Default)]
pub struct MlCoord(pub Vec<Coord>);

impl MlCoord {
    pub fn root() -> Self {
        MlCoord(Vec::new())
    }

    pub fn new(levels: Vec<Coord>) -> Self {
        MlCoord(levels)
    }

    /// Depth (number of levels descended from the root).
    pub fn depth(&self) -> usize {
        self.0.len()
    }

    pub fn is_root(&self) -> bool {
        self.0.is_empty()
    }

    /// Extend by one inner-level coordinate.
    pub fn child(&self, c: Coord) -> MlCoord {
        let mut v = self.0.clone();
        v.push(c);
        MlCoord(v)
    }

    /// Drop the innermost level (`None` at the root).
    pub fn parent(&self) -> Option<MlCoord> {
        if self.0.is_empty() {
            return None;
        }
        let mut v = self.0.clone();
        v.pop();
        Some(MlCoord(v))
    }

    /// Coordinate at level `i` (0 = outermost).
    pub fn level(&self, i: usize) -> Option<&Coord> {
        self.0.get(i)
    }

    /// Innermost coordinate.
    pub fn leaf(&self) -> Option<&Coord> {
        self.0.last()
    }

    /// Longest common prefix depth with another multi-level coordinate —
    /// the level of the lowest common ancestor matrix.
    pub fn common_depth(&self, other: &MlCoord) -> usize {
        self.0
            .iter()
            .zip(&other.0)
            .take_while(|(a, b)| a == b)
            .count()
    }

    /// True if `self` is a (strict or equal) prefix of `other`.
    pub fn is_prefix_of(&self, other: &MlCoord) -> bool {
        self.0.len() <= other.0.len() && self.common_depth(other) == self.0.len()
    }

    /// Truncate to the outermost `depth` levels.
    pub fn prefix(&self, depth: usize) -> MlCoord {
        MlCoord(self.0[..depth.min(self.0.len())].to_vec())
    }
}

impl fmt::Display for MlCoord {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.0.is_empty() {
            return write!(f, "(root)");
        }
        for (i, c) in self.0.iter().enumerate() {
            if i > 0 {
                write!(f, "→")?;
            }
            write!(f, "{}", c)?;
        }
        Ok(())
    }
}

impl From<Vec<Vec<u32>>> for MlCoord {
    fn from(v: Vec<Vec<u32>>) -> Self {
        MlCoord(v.into_iter().map(Coord).collect())
    }
}

/// Convenience constructor: `mlc![[0,0],[1,2]]`-style via slices.
pub fn mlc(levels: &[&[u32]]) -> MlCoord {
    MlCoord(levels.iter().map(|l| Coord(l.to_vec())).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linearize_roundtrip() {
        let shape = [3usize, 4, 5];
        for idx in 0..60 {
            let c = Coord::from_linear(idx, &shape).unwrap();
            assert_eq!(c.linearize(&shape), Some(idx));
        }
        assert_eq!(Coord::from_linear(60, &shape), None);
        assert_eq!(Coord::new(vec![3, 0, 0]).linearize(&shape), None);
        assert_eq!(Coord::new(vec![0, 0]).linearize(&shape), None);
    }

    #[test]
    fn manhattan_and_torus() {
        let a = Coord::new(vec![0, 0]);
        let b = Coord::new(vec![3, 1]);
        assert_eq!(a.manhattan(&b), 4);
        // 4-wide torus: distance 3 wraps to 1.
        assert_eq!(a.torus_distance(&b, &[4, 4]), 2);
    }

    #[test]
    fn mlcoord_navigation() {
        let m = mlc(&[&[0, 1], &[2, 3]]);
        assert_eq!(m.depth(), 2);
        assert_eq!(m.leaf(), Some(&Coord::new(vec![2, 3])));
        assert_eq!(m.parent().unwrap(), mlc(&[&[0, 1]]));
        assert_eq!(m.parent().unwrap().parent().unwrap(), MlCoord::root());
        assert_eq!(MlCoord::root().parent(), None);
        let child = m.child(Coord::new(vec![4]));
        assert_eq!(child.depth(), 3);
        assert!(m.is_prefix_of(&child));
        assert!(!child.is_prefix_of(&m));
    }

    #[test]
    fn common_depth() {
        let a = mlc(&[&[0], &[1], &[2]]);
        let b = mlc(&[&[0], &[1], &[3]]);
        let c = mlc(&[&[1]]);
        assert_eq!(a.common_depth(&b), 2);
        assert_eq!(a.common_depth(&c), 0);
        assert_eq!(a.common_depth(&a), 3);
        assert_eq!(a.prefix(2), mlc(&[&[0], &[1]]));
    }

    #[test]
    fn display_forms() {
        assert_eq!(mlc(&[&[0, 0], &[2, 1]]).to_string(), "(0,0)→(2,1)");
        assert_eq!(MlCoord::root().to_string(), "(root)");
    }

    #[test]
    fn prop_linearize_bijection() {
        use crate::util::propcheck::{check, Gen};
        check("coord linearize bijective", 128, |g: &mut Gen| {
            let ndim = g.usize(1..=4);
            let shape: Vec<usize> = (0..ndim).map(|_| g.usize(1..=6)).collect();
            let total: usize = shape.iter().product();
            let idx = g.usize(0..=total - 1);
            let c = Coord::from_linear(idx, &shape).ok_or("from_linear failed")?;
            if c.linearize(&shape) == Some(idx) {
                Ok(())
            } else {
                Err(format!("roundtrip failed for {idx} in {shape:?}"))
            }
        });
    }
}
