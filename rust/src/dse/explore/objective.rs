//! Objectives: scalar figures of merit over a materialized design and its
//! simulation result. All objectives are minimized; multi-objective
//! exploration reports a Pareto front over the whole objective vector.

use crate::sim::SimResult;

use super::space::DesignView;

/// A figure of merit (lower is better) computed from one simulation.
///
/// Scoring takes a borrowed [`DesignView`] (not an owned `Design`): with
/// topology-keyed setup reuse, the hardware model and graph skeleton live
/// once per topology and only the mapping is per-candidate, so objectives
/// must not assume per-candidate ownership.
pub trait Objective: Send + Sync {
    fn name(&self) -> &str;

    /// Score a design; return `f64::INFINITY` for infeasible designs.
    fn score(&self, design: &DesignView, sim: &SimResult) -> f64;
}

/// Simulated makespan in cycles.
pub struct Makespan;

impl Objective for Makespan {
    fn name(&self) -> &str {
        "makespan"
    }

    fn score(&self, _design: &DesignView, sim: &SimResult) -> f64 {
        sim.makespan
    }
}

/// Energy-delay product: total energy (pJ) × makespan (cycles).
pub struct Edp;

impl Objective for Edp {
    fn name(&self) -> &str {
        "edp"
    }

    fn score(&self, _design: &DesignView, sim: &SimResult) -> f64 {
        sim.total_energy() * sim.makespan
    }
}

/// Makespan subject to a silicon-area budget: designs whose reported area
/// exceeds the budget are infeasible. Designs without an area figure pass
/// unconstrained.
pub struct AreaConstrainedMakespan {
    pub budget_mm2: f64,
    name: String,
}

impl AreaConstrainedMakespan {
    pub fn new(budget_mm2: f64) -> AreaConstrainedMakespan {
        AreaConstrainedMakespan {
            budget_mm2,
            name: format!("makespan@area<={budget_mm2:.0}mm2"),
        }
    }
}

impl Objective for AreaConstrainedMakespan {
    fn name(&self) -> &str {
        &self.name
    }

    fn score(&self, design: &DesignView, sim: &SimResult) -> f64 {
        match design.area_mm2 {
            Some(a) if a > self.budget_mm2 => f64::INFINITY,
            _ => sim.makespan,
        }
    }
}

/// Manufacturing cost in dollars (infeasible when the space attaches no
/// cost model).
pub struct CostUsd;

impl Objective for CostUsd {
    fn name(&self) -> &str {
        "cost_usd"
    }

    fn score(&self, design: &DesignView, _sim: &SimResult) -> f64 {
        design.cost_usd.unwrap_or(f64::INFINITY)
    }
}

#[cfg(test)]
mod tests {
    use super::super::space::{placement_demo, Design, DesignSpace};
    use super::*;
    use crate::eval::Registry;
    use crate::sim::{simulate, SimConfig};

    fn sample() -> (Design, SimResult) {
        let space = placement_demo("obj-test", (2, 2), 2);
        let d = space.materialize(&space.initial()).unwrap();
        let r = simulate(
            &d.workload.hw,
            &d.workload.graph,
            &d.workload.mapping,
            &Registry::standard(),
            &SimConfig::default(),
        )
        .unwrap();
        (d, r)
    }

    #[test]
    fn makespan_and_edp_positive() {
        let (d, r) = sample();
        assert!(Makespan.score(&d.view(), &r) > 0.0);
        assert!(Edp.score(&d.view(), &r) > Makespan.score(&d.view(), &r));
    }

    #[test]
    fn area_constraint_gates() {
        let (mut d, r) = sample();
        d.area_mm2 = Some(100.0);
        let tight = AreaConstrainedMakespan::new(50.0);
        let loose = AreaConstrainedMakespan::new(200.0);
        assert!(tight.score(&d.view(), &r).is_infinite());
        assert_eq!(loose.score(&d.view(), &r), r.makespan);
        assert!(tight.name().contains("50"));
        // no area figure -> unconstrained
        d.area_mm2 = None;
        assert_eq!(tight.score(&d.view(), &r), r.makespan);
    }

    #[test]
    fn cost_requires_cost_model() {
        let (mut d, r) = sample();
        assert!(CostUsd.score(&d.view(), &r).is_infinite());
        d.cost_usd = Some(42.0);
        assert_eq!(CostUsd.score(&d.view(), &r), 42.0);
    }
}
