//! Chrome-trace export of simulation timelines.
//!
//! Converts a [`SimResult`] collected with `collect_timeline = true` into
//! the Chrome tracing JSON format (`chrome://tracing`, Perfetto): one
//! "process" per `SpacePoint`, one duration event per task evaluation.
//! Handy for eyeballing contention, pipeline bubbles, and the DRAM
//! bottleneck of the §7.4 temporal baseline.

use crate::hwir::Hardware;
use crate::taskgraph::TaskGraph;
use crate::util::json::{Json, JsonObj};

use super::engine::SimResult;

/// Build the Chrome-trace JSON document.
pub fn chrome_trace(result: &SimResult, hw: &Hardware, graph: &TaskGraph) -> Json {
    let mut events = Vec::with_capacity(result.timeline.len() + hw.num_points());

    // Process metadata: name each SpacePoint lane.
    for entry in hw.entries() {
        let mut meta = JsonObj::new();
        meta.insert("name", "process_name".into());
        meta.insert("ph", "M".into());
        meta.insert("pid", (entry.id.0 as u64).into());
        let mut args = JsonObj::new();
        args.insert(
            "name",
            format!("{} {}", entry.point.name, entry.addr).into(),
        );
        meta.insert("args", Json::Obj(args));
        events.push(Json::Obj(meta));
    }

    for ev in &result.timeline {
        let mut e = JsonObj::new();
        let name = graph
            .get(ev.task)
            .map(|t| t.name.clone())
            .unwrap_or_else(|| format!("{}", ev.task));
        e.insert("name", name.into());
        e.insert("cat", graph.get(ev.task).map(|t| t.kind.kind_name()).unwrap_or("task").into());
        e.insert("ph", "X".into());
        e.insert("pid", (ev.point.0 as u64).into());
        e.insert("tid", (ev.iter as u64).into());
        // Chrome traces are in microseconds; keep cycles 1:1.
        e.insert("ts", ev.start.into());
        e.insert("dur", (ev.end - ev.start).max(0.0).into());
        events.push(Json::Obj(e));
    }

    let mut doc = JsonObj::new();
    doc.insert("traceEvents", Json::Arr(events));
    doc.insert("displayTimeUnit", "ns".into());
    Json::Obj(doc)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::Registry;
    use crate::hwir::{ComputeAttrs, Coord, Element, MemoryAttrs, SpaceMatrix, SpacePoint};
    use crate::mapping::Mapping;
    use crate::sim::{simulate, SimConfig};
    use crate::taskgraph::{ComputeCost, OpClass, TaskKind};

    #[test]
    fn trace_roundtrips_as_json() {
        let mut m = SpaceMatrix::new("chip", vec![1]);
        m.set(
            Coord::new(vec![0]),
            Element::Point(SpacePoint::compute(
                "core",
                ComputeAttrs::new((4, 4), 8).with_lmem(MemoryAttrs::new(1 << 20, 64.0, 0)),
            )),
        );
        let hw = Hardware::build(m);
        let mut g = TaskGraph::new();
        let mut c = ComputeCost::zero(OpClass::Elementwise);
        c.vec_flops = 160.0;
        let a = g.add("a", TaskKind::Compute(c));
        let b = g.add("b", TaskKind::Compute(c));
        g.connect(a, b);
        let mut map = Mapping::new();
        let core = hw.points_of_kind("compute")[0];
        map.map(a, core);
        map.map(b, core);
        let cfg = SimConfig {
            collect_timeline: true,
            ..Default::default()
        };
        let r = simulate(&hw, &g, &map, &Registry::standard(), &cfg).unwrap();
        assert_eq!(r.timeline.len(), 2);
        let doc = chrome_trace(&r, &hw, &g);
        let text = doc.to_pretty();
        let parsed = Json::parse(&text).unwrap();
        let events = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        // 1 metadata event per point + 2 task events
        assert_eq!(events.len(), hw.num_points() + 2);
        // task events carry durations
        let durs: Vec<f64> = events
            .iter()
            .filter(|e| e.get("ph").and_then(Json::as_str) == Some("X"))
            .map(|e| e.get("dur").unwrap().as_f64().unwrap())
            .collect();
        assert_eq!(durs.len(), 2);
        assert!(durs.iter().all(|d| *d > 0.0));
    }
}
