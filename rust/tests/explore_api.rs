//! Integration tests of the public exploration API: explorer determinism
//! across worker counts and repeated seeded runs, and memo-cache
//! correctness measured with a probe evaluator.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use mldse::dse::explore::{
    explore, placement_demo, AnnealExplorer, Axis, AxisKind, Candidate, Design, DesignSpace,
    Edp, ExplorationReport, ExploreOpts, Explorer, GridExplorer, HillClimbExplorer, Makespan,
    Objective, RandomExplorer,
};
use mldse::eval::roofline::RooflineEvaluator;
use mldse::eval::{Demand, Evaluator, Registry};
use mldse::hwir::{ComputeAttrs, Coord, Element, Hardware, MemoryAttrs, SpaceMatrix, SpacePoint};
use mldse::mapping::Mapping;
use mldse::taskgraph::{ComputeCost, OpClass, TaskGraph, TaskKind};
use mldse::workloads::Workload;

/// A cheap synthetic space implemented purely through the public API: one
/// compute task on one core whose work grows with the distance from a
/// target digit pair.
struct ParaboloidSpace {
    axes: Vec<Axis>,
    target: (u32, u32),
}

impl ParaboloidSpace {
    fn new(w: u64, h: u64, target: (u32, u32)) -> ParaboloidSpace {
        let xs: Vec<u64> = (0..w).collect();
        let ys: Vec<u64> = (0..h).collect();
        ParaboloidSpace {
            axes: vec![
                Axis::u64s("x", AxisKind::HwParam, &xs),
                Axis::u64s("y", AxisKind::HwParam, &ys),
            ],
            target,
        }
    }
}

impl DesignSpace for ParaboloidSpace {
    fn name(&self) -> &str {
        "paraboloid"
    }

    fn axes(&self) -> &[Axis] {
        &self.axes
    }

    fn materialize(&self, c: &Candidate) -> mldse::util::error::Result<Design> {
        let dx = c.0[0] as f64 - self.target.0 as f64;
        let dy = c.0[1] as f64 - self.target.1 as f64;
        let mut m = SpaceMatrix::new("chip", vec![1]);
        m.set(
            Coord::new(vec![0]),
            Element::Point(SpacePoint::compute(
                "core",
                ComputeAttrs::new((8, 8), 32).with_lmem(MemoryAttrs::new(1 << 20, 512.0, 1)),
            )),
        );
        let hw = Hardware::build(m);
        let core = hw.points_of_kind("compute")[0];
        let mut graph = TaskGraph::new();
        let mut cost = ComputeCost::zero(OpClass::Elementwise);
        cost.vec_flops = 10_000.0 * (1.0 + dx * dx + dy * dy);
        let t = graph.add("work", TaskKind::Compute(cost));
        let mut mapping = Mapping::new();
        mapping.map(t, core);
        Ok(Design::new(Workload {
            hw,
            graph,
            mapping,
            name: "paraboloid".into(),
            notes: Vec::new(),
        }))
    }
}

fn objectives() -> Vec<Box<dyn Objective>> {
    vec![Box::new(Makespan), Box::new(Edp)]
}

fn run(
    space: &dyn DesignSpace,
    explorer: &dyn Explorer,
    budget: usize,
    workers: usize,
    registry: &Registry,
    cache: bool,
) -> ExplorationReport {
    let objs = objectives();
    let opts = ExploreOpts {
        budget,
        workers,
        cache,
        ..Default::default()
    };
    explore(space, &objs, explorer, registry, &opts).unwrap()
}

/// Bit-exact comparison of two exploration logs: same candidates, in the
/// same order, with bit-identical objective vectors, and the same best.
fn assert_identical(a: &ExplorationReport, b: &ExplorationReport) {
    assert_eq!(a.evals.len(), b.evals.len(), "eval log lengths differ");
    for (i, (x, y)) in a.evals.iter().zip(&b.evals).enumerate() {
        assert_eq!(x.candidate, y.candidate, "candidate {i} differs");
        assert_eq!(
            x.objectives.len(),
            y.objectives.len(),
            "objective arity at {i}"
        );
        for (u, v) in x.objectives.iter().zip(&y.objectives) {
            assert_eq!(u.to_bits(), v.to_bits(), "objective bits at eval {i}");
        }
    }
    assert_eq!(a.best_index(), b.best_index());
    assert_eq!(a.moves_accepted, b.moves_accepted);
}

#[test]
fn explorers_deterministic_across_worker_counts_and_reruns() {
    let space = ParaboloidSpace::new(6, 6, (4, 1));
    let registry = Registry::standard();
    let explorers: Vec<Box<dyn Explorer>> = vec![
        Box::new(GridExplorer),
        Box::new(RandomExplorer { seed: 42 }),
        Box::new(HillClimbExplorer {
            seed: 42,
            from_initial: false,
            restarts: true,
        }),
        Box::new(AnnealExplorer {
            seed: 42,
            init_temp: 0.1,
            tiered: false,
        }),
    ];
    for explorer in &explorers {
        let serial = run(&space, explorer.as_ref(), 30, 1, &registry, true);
        let parallel = run(&space, explorer.as_ref(), 30, 8, &registry, true);
        let repeat = run(&space, explorer.as_ref(), 30, 8, &registry, true);
        assert!(!serial.evals.is_empty(), "{}", explorer.name());
        assert_identical(&serial, &parallel);
        assert_identical(&parallel, &repeat);
    }
}

#[test]
fn placement_space_deterministic_too() {
    // the mapping tier goes through the same engine: spot-check with the
    // annealer on a real placement problem
    let space = placement_demo("det-check", (2, 2), 6);
    let registry = Registry::standard();
    let annealer = AnnealExplorer {
        seed: 7,
        init_temp: 0.1,
        tiered: false,
    };
    let a = run(&space, &annealer, 25, 1, &registry, true);
    let b = run(&space, &annealer, 25, 8, &registry, true);
    assert_identical(&a, &b);
}

/// Probe evaluator: forwards to the standard roofline model while counting
/// demand queries — a direct measure of how many candidate simulations
/// actually ran.
struct Probe {
    calls: Arc<AtomicUsize>,
    inner: RooflineEvaluator,
}

impl Evaluator for Probe {
    fn demand(&self, task: &mldse::taskgraph::Task, point: &mldse::hwir::PointEntry) -> Demand {
        self.calls.fetch_add(1, Ordering::SeqCst);
        self.inner.demand(task, point)
    }

    fn name(&self) -> &str {
        "probe"
    }
}

fn probe_registry() -> (Registry, Arc<AtomicUsize>) {
    let calls = Arc::new(AtomicUsize::new(0));
    let registry = Registry::new(Box::new(Probe {
        calls: calls.clone(),
        inner: RooflineEvaluator::default(),
    }));
    (registry, calls)
}

#[test]
fn memo_cache_preserves_values_with_strictly_fewer_simulations() {
    // 16-candidate space, 40 proposals: repeats are guaranteed, so the
    // cached run must simulate strictly less than the uncached one.
    let space = ParaboloidSpace::new(4, 4, (1, 2));
    let explorer = RandomExplorer { seed: 9 };

    let (registry, probe_uncached) = probe_registry();
    let uncached = run(&space, &explorer, 40, 4, &registry, false);

    let (registry, probe_cached) = probe_registry();
    let cached = run(&space, &explorer, 40, 4, &registry, true);

    // identical objective values eval-by-eval
    assert_identical(&uncached, &cached);

    // strictly fewer simulate invocations, measured both by the engine's
    // own counter and by the probe evaluator
    assert_eq!(uncached.sim_calls, 40);
    assert!(cached.sim_calls <= 16);
    assert!(
        cached.sim_calls < uncached.sim_calls,
        "{} vs {}",
        cached.sim_calls,
        uncached.sim_calls
    );
    let u = probe_uncached.load(Ordering::SeqCst);
    let c = probe_cached.load(Ordering::SeqCst);
    assert!(c < u, "probe: cached {c} vs uncached {u}");
    assert!(c > 0);

    // cache accounting adds up
    assert_eq!(cached.sim_calls + cached.cache_hits, cached.evals.len());
    assert!(cached.cache_hits > 0);
}

#[test]
fn grid_cache_is_transparent_for_unique_candidates() {
    let space = ParaboloidSpace::new(3, 3, (0, 0));
    let registry = Registry::standard();
    let with_cache = run(&space, &GridExplorer, 9, 2, &registry, true);
    let without = run(&space, &GridExplorer, 9, 2, &registry, false);
    assert_identical(&with_cache, &without);
    // no repeats in a grid enumeration: cache changes nothing
    assert_eq!(with_cache.sim_calls, 9);
    assert_eq!(without.sim_calls, 9);
    assert_eq!(with_cache.cache_hits, 0);
}
