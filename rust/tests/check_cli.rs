//! End-to-end tests of `mldse check` against the real binary
//! (`CARGO_BIN_EXE_mldse`): a table-driven sweep pinning every diagnostic
//! code to a fixture under `rust/tests/fixtures/check/` (exact code +
//! severity + sniffed input kind, via `--json`), `--deny-warnings`
//! semantics, multi-file output shape, the `explore --space` pre-flight,
//! and a clean-fixture pass proving every shipped space and scenario JSON
//! produces zero diagnostics.

use std::path::PathBuf;
use std::process::{Command, Output};

use mldse::analyze::diag::CODE_TABLE;
use mldse::util::json::Json;

fn mldse() -> Command {
    let mut cmd = Command::new(env!("CARGO_BIN_EXE_mldse"));
    // isolate from the ambient environment
    cmd.env_remove("MLDSE_WORKERS");
    cmd
}

fn fixture(name: &str) -> String {
    format!(
        "{}/rust/tests/fixtures/check/{name}",
        env!("CARGO_MANIFEST_DIR")
    )
}

/// Every file-reachable diagnostic code with its fixture, expected
/// severity, and the input kind `check` should sniff (`""` for the
/// not-JSON case, where no kind exists). The three task-graph integrity
/// codes (`MLDSE-E060`..`E062`) describe in-memory corruption that no
/// parseable document can express; they are pinned by unit tests on
/// `TaskGraph::validate` instead.
const CASES: &[(&str, &str, &str, &str)] = &[
    ("e001_not_json.json", "MLDSE-E001", "error", ""),
    ("e010_spec_invalid.json", "MLDSE-E010", "error", "hardware spec"),
    ("w011_shadowed_name.json", "MLDSE-W011", "warning", "hardware spec"),
    ("w012_unreachable.json", "MLDSE-W012", "warning", "hardware spec"),
    ("w013_zero_resource.json", "MLDSE-W013", "warning", "hardware spec"),
    ("w014_empty_sync_group.json", "MLDSE-W014", "warning", "hardware spec"),
    ("e020_program_invalid.json", "MLDSE-E020", "error", "mapping program"),
    ("e021_deadlock_cycle.json", "MLDSE-E021", "error", "mapping program"),
    ("e022_unmapped_task.json", "MLDSE-E022", "error", "mapping program"),
    ("e023_kind_mismatch.json", "MLDSE-E023", "error", "mapping program"),
    ("e024_replay_failed.json", "MLDSE-E024", "error", "mapping program"),
    (
        "w025_disabled_live_consumers.json",
        "MLDSE-W025",
        "warning",
        "mapping program",
    ),
    ("w030_over_capacity.json", "MLDSE-W030", "warning", "mapping program"),
    ("w031_link_bound.json", "MLDSE-W031", "warning", "mapping program"),
    ("e040_space_invalid.json", "MLDSE-E040", "error", "design space"),
    ("w041_dead_axis.json", "MLDSE-W041", "warning", "design space"),
    (
        "w042_cardinality_overflow.json",
        "MLDSE-W042",
        "warning",
        "design space",
    ),
    ("e050_scenario_invalid.json", "MLDSE-E050", "error", "bench scenario"),
    ("w051_partial_grid.json", "MLDSE-W051", "warning", "bench scenario"),
    (
        "e052_scenario_space_file.json",
        "MLDSE-E052",
        "error",
        "bench scenario",
    ),
    (
        "w053_surrogate_warmup.json",
        "MLDSE-W053",
        "warning",
        "bench scenario",
    ),
];

fn check_json(path: &str, extra: &[&str]) -> (Output, Json) {
    let out = mldse()
        .args(["check", path, "--json"])
        .args(extra)
        .output()
        .expect("run mldse");
    let stdout = String::from_utf8_lossy(&out.stdout);
    let doc = Json::parse(&stdout)
        .unwrap_or_else(|e| panic!("check --json output is not JSON ({e}):\n{stdout}"));
    (out, doc)
}

fn diag_codes(doc: &Json) -> Vec<(String, String)> {
    doc.get("diagnostics")
        .and_then(Json::as_arr)
        .expect("payload has a diagnostics array")
        .iter()
        .map(|d| {
            (
                d.get("code").and_then(Json::as_str).unwrap().to_string(),
                d.get("severity").and_then(Json::as_str).unwrap().to_string(),
            )
        })
        .collect()
}

#[test]
fn every_code_is_pinned_by_a_fixture() {
    for (file, code, severity, kind) in CASES {
        let path = fixture(file);
        let (out, doc) = check_json(&path, &[]);
        let found = diag_codes(&doc);
        assert!(
            found.iter().any(|(c, s)| c == code && s == severity),
            "{file}: expected {code} ({severity}), got {found:?}"
        );
        // errors fail the process; warnings alone pass (without
        // --deny-warnings)
        let has_error = found.iter().any(|(_, s)| s == "error");
        assert_eq!(
            out.status.success(),
            !has_error,
            "{file}: exit status disagrees with {found:?}\nstderr: {}",
            String::from_utf8_lossy(&out.stderr)
        );
        let sniffed = doc.get("kind").and_then(Json::as_str);
        if kind.is_empty() {
            assert_eq!(sniffed, None, "{file}: unparseable input has no kind");
        } else {
            assert_eq!(sniffed, Some(*kind), "{file}");
        }
    }
}

#[test]
fn fixture_table_covers_the_whole_code_table() {
    // Every registered code is either pinned by a fixture above or is one
    // of the graph-integrity codes pinned by TaskGraph::validate's unit
    // test. A new code without a fixture fails here.
    let unit_tested = ["MLDSE-E060", "MLDSE-E061", "MLDSE-E062"];
    for (code, _, _) in CODE_TABLE {
        let covered = CASES.iter().any(|(_, c, _, _)| c == code)
            || unit_tested.contains(code);
        assert!(covered, "registered code {code} has no fixture");
    }
    // and no fixture pins an unregistered code
    for (file, code, _, _) in CASES {
        assert!(
            CODE_TABLE.iter().any(|(c, _, _)| c == code),
            "{file} pins unregistered code {code}"
        );
    }
}

#[test]
fn deny_warnings_turns_warnings_into_failure() {
    let path = fixture("w041_dead_axis.json");
    let (out, _) = check_json(&path, &[]);
    assert!(out.status.success(), "warnings alone must pass");
    let (out, _) = check_json(&path, &["--deny-warnings"]);
    assert!(!out.status.success(), "--deny-warnings must fail");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("--deny-warnings"), "{stderr}");
}

#[test]
fn barrier_cycle_and_over_capacity_are_rejected_statically() {
    // The ISSUE-level acceptance pair: a deadlocked mapping program is an
    // error outright, and an over-capacity tile blocks under
    // --deny-warnings — both in milliseconds, with no simulation run.
    let out = mldse()
        .args(["check", &fixture("e021_deadlock_cycle.json")])
        .output()
        .expect("run mldse");
    assert!(!out.status.success());
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("MLDSE-E021"), "{stdout}");
    assert!(stdout.contains("deadlock"), "{stdout}");

    let out = mldse()
        .args([
            "check",
            &fixture("w030_over_capacity.json"),
            "--deny-warnings",
        ])
        .output()
        .expect("run mldse");
    assert!(!out.status.success());
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("MLDSE-W030"), "{stdout}");
}

#[test]
fn multiple_files_emit_a_json_array() {
    let a = fixture("e040_space_invalid.json");
    let b = fixture("w041_dead_axis.json");
    let out = mldse()
        .args(["check", &a, &b, "--json"])
        .output()
        .expect("run mldse");
    assert!(!out.status.success(), "one file has errors");
    let stdout = String::from_utf8_lossy(&out.stdout);
    let doc = Json::parse(&stdout).expect("array payload");
    let arr = doc.as_arr().expect("multi-file output is an array");
    assert_eq!(arr.len(), 2);
    assert_eq!(arr[0].get("origin").and_then(Json::as_str), Some(a.as_str()));
    assert_eq!(arr[1].get("origin").and_then(Json::as_str), Some(b.as_str()));
}

#[test]
fn table_mode_prints_ok_line_for_clean_input() {
    let path = format!(
        "{}/examples/spaces/three_tier_quick.json",
        env!("CARGO_MANIFEST_DIR")
    );
    let out = mldse().args(["check", &path]).output().expect("run mldse");
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("ok (design space)"), "{stdout}");
}

/// Every shipped declarative artifact is clean — zero diagnostics even
/// under `--deny-warnings`. This is what the CI `check` job enforces in
/// release mode.
#[test]
fn shipped_spaces_and_scenarios_are_clean() {
    let mut files: Vec<PathBuf> = Vec::new();
    for dir in ["examples/spaces", "benches/scenarios"] {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join(dir);
        for entry in std::fs::read_dir(&dir).expect("shipped dir exists") {
            let path = entry.expect("dir entry").path();
            if path.extension().is_some_and(|e| e == "json") {
                files.push(path);
            }
        }
    }
    files.sort();
    assert!(files.len() >= 6, "expected the shipped set, got {files:?}");
    let mut cmd = mldse();
    cmd.args(["check", "--deny-warnings"]);
    for f in &files {
        cmd.arg(f);
    }
    let out = cmd.output().expect("run mldse");
    assert!(
        out.status.success(),
        "shipped artifacts are not clean\nstdout: {}\nstderr: {}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    let stdout = String::from_utf8_lossy(&out.stdout);
    for f in &files {
        assert!(
            stdout.contains(&format!("check {}: ok", f.display())),
            "no ok line for {}:\n{stdout}",
            f.display()
        );
    }
}

#[test]
fn explore_preflight_rejects_a_bad_space_file() {
    let out = mldse()
        .args([
            "explore",
            "--space",
            &fixture("e040_space_invalid.json"),
            "--budget",
            "4",
        ])
        .output()
        .expect("run mldse");
    assert!(!out.status.success());
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("MLDSE-E040"), "{stderr}");
    assert!(stderr.contains("failed static checks"), "{stderr}");
}

#[test]
fn usage_errors_are_named() {
    let out = mldse().args(["check"]).output().expect("run mldse");
    assert!(!out.status.success());
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("at least one FILE.json"), "{stderr}");

    let out = mldse()
        .args(["check", "no/such/file.json"])
        .output()
        .expect("run mldse");
    assert!(!out.status.success());
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("check: reading 'no/such/file.json'"), "{stderr}");
}
