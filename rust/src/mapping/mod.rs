//! Spatiotemporal mapping (paper §5).
//!
//! [`ir`] defines the mapping IR: task→point assignment, cross-level
//! communication decomposition records, and multi-level time coordinates
//! with virtual-group synchronization lowering. [`primitives`] implements
//! the sixteen Table-1 mapping action primitives over a [`MappingState`]
//! with undo/redo, the substrate user search algorithms are built from.
//! [`program`] lifts the primitives into a serializable, parameterized
//! [`MappingProgram`] IR — the mapping-exploration substrate that
//! `dse::explore::ProgramSpace` exposes as a design space.

pub mod ir;
pub mod primitives;
pub mod program;

pub use ir::{lower_time_coords, Mapping, TimeCoord};
pub use primitives::{MapError, MappingState};
pub use program::{placement_program, MappingProgram, Param, ParamDomain, Prim, TaskSel};
