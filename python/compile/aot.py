"""AOT compile path: lower the Layer-2 evaluator to HLO text artifacts.

Run once by `make artifacts`; the Rust runtime loads the text with
`HloModuleProto::from_text_file`. HLO *text* (never `.serialize()`) is the
interchange format — jax >= 0.5 emits protos with 64-bit instruction ids
that xla_extension 0.5.1 rejects, while the text parser reassigns ids
(see /opt/xla-example/README.md).

Usage: python -m compile.aot --out-dir ../artifacts
"""

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from . import model

BATCH = 128  # must match rust/src/eval/pjrt.rs::BATCH


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_evaluator(batch: int) -> str:
    desc = jax.ShapeDtypeStruct((batch, 8), jax.numpy.float32)
    hw = jax.ShapeDtypeStruct((7,), jax.numpy.float32)
    lowered = jax.jit(model.evaluate_batch).lower(desc, hw)
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--batch", type=int, default=BATCH)
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    path = os.path.join(args.out_dir, f"evaluator_b{args.batch}.hlo.txt")
    text = lower_evaluator(args.batch)
    with open(path, "w") as f:
        f.write(text)
    print(f"wrote {len(text)} chars to {path}")


if __name__ == "__main__":
    main()
