//! Collective-communication task graphs (paper §7.2, Eq. 7 validation).
//!
//! Expands a ring All-Reduce (reduce-scatter + all-gather) into an explicit
//! task graph over `n` device cells connected by a communication point, so
//! the event-driven simulation can be validated against the closed-form
//! latency-bandwidth models in [`crate::eval::comm`] (<3% target).

use crate::hwir::{Hardware, MlCoord};
use crate::mapping::Mapping;
use crate::taskgraph::{TaskGraph, TaskId, TaskKind};

/// Build a ring All-Reduce task graph over the device cells `devices`
/// (addressed within the level whose comm point carries the transfers).
///
/// The collective is 2(n-1) steps; in step `s`, device `d` sends one
/// `bytes/n` shard to device `(d+1) % n`. Steps are dependency-chained per
/// device pair, matching the synchronous ring schedule the closed form
/// assumes. Returns the sink tasks (one per device).
pub fn ring_all_reduce(
    hw: &Hardware,
    graph: &mut TaskGraph,
    mapping: &mut Mapping,
    devices: &[MlCoord],
    bytes: u64,
) -> Vec<TaskId> {
    let n = devices.len();
    assert!(n >= 2, "all-reduce needs >= 2 devices");
    let shard = (bytes / n as u64).max(1);
    let steps = 2 * (n - 1);

    // last task per device (starts as a zero-cost source marker)
    let mut last: Vec<Option<TaskId>> = vec![None; n];
    let mut sinks = Vec::new();

    for step in 0..steps {
        let mut this: Vec<Option<TaskId>> = vec![None; n];
        for d in 0..n {
            let dst = (d + 1) % n;
            let segs = hw.route(&devices[d], &devices[dst]);
            let mut prev: Option<TaskId> = None;
            for (i, seg) in segs.iter().enumerate() {
                let id = graph.add(
                    format!("ar-s{step}-d{d}/{i}"),
                    TaskKind::Comm {
                        bytes: shard,
                        hops: seg.hops,
                        route: Some((seg.from.clone(), seg.to.clone())),
                    },
                );
                mapping.map(id, seg.comm);
                // chain within the route
                if let Some(p) = prev {
                    graph.connect(p, id);
                }
                prev = Some(id);
            }
            let head = segs.first().map(|_| ()).and(prev); // tail of route
            // step s of device d depends on step s-1 of d (its own send)
            // and of (d-1) (the shard it forwards arrived)
            if let Some(first_seg_task) = route_head(graph, &head, segs.len()) {
                if let Some(p) = last[d] {
                    graph.connect(p, first_seg_task);
                }
                let src_prev = (d + n - 1) % n;
                if let Some(p) = last[src_prev] {
                    if p != first_seg_task {
                        graph.connect(p, first_seg_task);
                    }
                }
            }
            this[d] = head;
        }
        last = this;
    }
    for t in last.into_iter().flatten() {
        sinks.push(t);
    }
    sinks
}

/// Helper: recover the first task of the route chain whose tail is `tail`.
fn route_head(graph: &TaskGraph, tail: &Option<TaskId>, route_len: usize) -> Option<TaskId> {
    let mut cur = (*tail)?;
    for _ in 1..route_len {
        let preds = graph.predecessors(cur);
        // the within-route predecessor was connected first
        cur = *preds.first()?;
    }
    Some(cur)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::comm::{ring_all_reduce as ring_closed_form, LinkModel};
    use crate::eval::Registry;
    use crate::hwir::{mlc, CommAttrs, ComputeAttrs, Coord, Element, SpaceMatrix, SpacePoint, Topology};
    use crate::sim::{simulate, SimConfig};

    /// `n` devices on a ring network.
    fn ring_hw(n: usize, bw: f64, lat: u64) -> Hardware {
        let mut m = SpaceMatrix::new("cluster", vec![n]);
        for i in 0..n {
            m.set(
                Coord::new(vec![i as u32]),
                Element::Point(SpacePoint::compute(
                    "dev",
                    ComputeAttrs::new((8, 8), 64),
                )),
            );
        }
        m.add_comm(SpacePoint::comm(
            "ring",
            CommAttrs::new(Topology::Ring, bw, lat),
        ));
        Hardware::build(m)
    }

    #[test]
    fn ring_all_reduce_matches_closed_form() {
        // E15: event-driven sim vs Eq. 7-family closed form, <3%.
        for n in [2usize, 4, 8] {
            let bw = 64.0;
            let lat = 10u64;
            let bytes = 4u64 << 20;
            let hw = ring_hw(n, bw, lat);
            let devices: Vec<MlCoord> = (0..n).map(|i| mlc(&[&[i as u32]])).collect();
            let mut graph = TaskGraph::new();
            let mut mapping = Mapping::new();
            let sinks =
                ring_all_reduce(&hw, &mut graph, &mut mapping, &devices, bytes);
            assert_eq!(sinks.len(), n);
            let r = simulate(&hw, &graph, &mapping, &Registry::standard(), &SimConfig::default())
                .unwrap();
            let expect = ring_closed_form(n, bytes as f64, LinkModel::new(lat as f64, bw));
            let rel = (r.makespan - expect).abs() / expect;
            assert!(
                rel < 0.03,
                "n={n}: sim {} vs closed form {} (rel {:.3})",
                r.makespan,
                expect,
                rel
            );
        }
    }

    #[test]
    fn all_reduce_needs_two_devices() {
        let hw = ring_hw(2, 8.0, 1);
        let devices: Vec<MlCoord> = (0..2).map(|i| mlc(&[&[i as u32]])).collect();
        let mut graph = TaskGraph::new();
        let mut mapping = Mapping::new();
        let sinks = ring_all_reduce(&hw, &mut graph, &mut mapping, &devices, 1024);
        assert_eq!(sinks.len(), 2);
        assert!(graph.toposort().is_some());
    }
}
