//! Static lints over hardware specs (the `{"matrix": …}` JSON form).
//!
//! These catch descriptions that parse fine but model hardware that cannot
//! work — or silently models something other than what the author meant:
//! reused point names with differing definitions (shadowing), levels whose
//! cells have no communication domain to reach each other, zero-capacity
//! or zero-bandwidth resources, and sync groups that resolve to nothing.

use std::collections::HashMap;

use crate::hwir::{parse_spec_value, Element, Hardware, PointKind, SpaceMatrix};
use crate::util::json::Json;

use super::diag::{self, Diagnostic};

/// Run every hardware-spec check on an already-parsed JSON document.
/// Returns a sorted diagnostic list (empty = clean).
pub fn check_spec_doc(doc: &Json) -> Vec<Diagnostic> {
    let matrix = match parse_spec_value(doc) {
        Ok(m) => m,
        Err(e) => {
            return vec![Diagnostic::error(diag::E010_SPEC_INVALID, "", e.to_string())];
        }
    };
    let mut diags = Vec::new();
    lint_levels(&matrix, &matrix.name, &mut diags);
    let hw = Hardware::build(matrix);
    lint_points(&hw, &mut diags);
    lint_sync_groups(&hw, &mut diags);
    diag::sort(&mut diags);
    diags
}

/// W012: a matrix level with more than one occupied cell but no
/// communication point — its cells cannot exchange data within the level.
fn lint_levels(m: &SpaceMatrix, path: &str, diags: &mut Vec<Diagnostic>) {
    let occupied = m.iter_cells().count();
    if occupied > 1 && m.comms.is_empty() {
        diags.push(Diagnostic::warning(
            diag::W012_UNREACHABLE,
            path,
            format!(
                "matrix '{}' has {occupied} occupied cells but no communication \
                 point; intra-level transfers are unroutable",
                m.name
            ),
        ));
    }
    for (coord, element) in m.iter_cells() {
        if let Element::Matrix(inner) = element {
            lint_levels(inner, &format!("{path}/{coord}"), diags);
        }
    }
}

/// W011 (shadowed names) and W013 (zero-capacity/zero-bandwidth resources)
/// over the built point registry.
fn lint_points(hw: &Hardware, diags: &mut Vec<Diagnostic>) {
    // Shadowing: the same point name bound to differing definitions. Names
    // are how mapping programs and sync groups refer to hardware, so two
    // different points sharing one name silently resolves to "both".
    let mut by_name: HashMap<&str, &crate::hwir::PointEntry> = HashMap::new();
    let mut warned: Vec<&str> = Vec::new();
    for e in hw.entries() {
        match by_name.get(e.point.name.as_str()) {
            None => {
                by_name.insert(&e.point.name, e);
            }
            Some(first) => {
                if first.point != e.point && !warned.contains(&e.point.name.as_str()) {
                    warned.push(&e.point.name);
                    diags.push(Diagnostic::warning(
                        diag::W011_SHADOWED_NAME,
                        format!("{}", e.addr),
                        format!(
                            "point name '{}' is reused with a different definition \
                             (first defined at {})",
                            e.point.name, first.addr
                        ),
                    ));
                }
            }
        }
    }

    for e in hw.entries() {
        let at = format!("{}", e.addr);
        let name = &e.point.name;
        match &e.point.kind {
            PointKind::Memory(a) | PointKind::Dram(a) => {
                if a.capacity == 0 {
                    diags.push(Diagnostic::warning(
                        diag::W013_ZERO_RESOURCE,
                        at.clone(),
                        format!("memory '{name}' has zero capacity"),
                    ));
                }
                if a.bandwidth <= 0.0 {
                    diags.push(Diagnostic::warning(
                        diag::W013_ZERO_RESOURCE,
                        at,
                        format!("memory '{name}' has zero bandwidth"),
                    ));
                }
            }
            PointKind::Compute(a) => {
                if let Some(lm) = &a.lmem {
                    if lm.capacity == 0 {
                        diags.push(Diagnostic::warning(
                            diag::W013_ZERO_RESOURCE,
                            at.clone(),
                            format!("lmem of compute point '{name}' has zero capacity"),
                        ));
                    }
                    if lm.bandwidth <= 0.0 {
                        diags.push(Diagnostic::warning(
                            diag::W013_ZERO_RESOURCE,
                            at,
                            format!("lmem of compute point '{name}' has zero bandwidth"),
                        ));
                    }
                }
            }
            PointKind::Comm(a) => {
                if a.link_bandwidth <= 0.0 {
                    diags.push(Diagnostic::warning(
                        diag::W013_ZERO_RESOURCE,
                        at,
                        format!("comm '{name}' has zero link bandwidth"),
                    ));
                }
            }
        }
    }
}

/// W014: a sync group whose member cells are all holes (or recursively
/// empty), so the group synchronizes nothing.
fn lint_sync_groups(hw: &Hardware, diags: &mut Vec<Diagnostic>) {
    for g in hw.sync_groups() {
        if g.points.is_empty() {
            diags.push(Diagnostic::warning(
                diag::W014_EMPTY_SYNC_GROUP,
                format!("sync_groups.{}", g.name),
                format!("sync group '{}' resolves to zero points", g.name),
            ));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analyze::diag::Severity;

    fn check(text: &str) -> Vec<Diagnostic> {
        check_spec_doc(&Json::parse(text).unwrap())
    }

    #[test]
    fn clean_spec_is_clean() {
        let d = check(
            r#"{"matrix": {"name": "chip", "dims": [2],
                "comms": [{"name": "noc", "topology": "mesh", "link_bandwidth": 32}],
                "fill": {"point": {"name": "core", "kind": "compute",
                                   "systolic": [4, 4]}}}}"#,
        );
        assert!(d.is_empty(), "{d:?}");
    }

    #[test]
    fn invalid_spec_is_e010() {
        let d = check(r#"{"matrix": {"name": "x"}}"#);
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].code, diag::E010_SPEC_INVALID);
        assert_eq!(d[0].severity, Severity::Error);
    }

    #[test]
    fn shadowed_name_is_w011() {
        let d = check(
            r#"{"matrix": {"name": "chip", "dims": [2],
                "comms": [{"name": "noc", "topology": "mesh", "link_bandwidth": 32}],
                "cells": [
                  {"at": [0], "point": {"name": "core", "kind": "compute",
                                        "systolic": [4, 4]}},
                  {"at": [1], "point": {"name": "core", "kind": "compute",
                                        "systolic": [8, 8]}}]}}"#,
        );
        assert_eq!(d.iter().filter(|x| x.code == diag::W011_SHADOWED_NAME).count(), 1);
        // Identical replicas (the `fill` idiom) must NOT warn.
        let clean = check(
            r#"{"matrix": {"name": "chip", "dims": [4],
                "comms": [{"name": "noc", "topology": "mesh", "link_bandwidth": 32}],
                "fill": {"point": {"name": "core", "kind": "compute",
                                   "systolic": [4, 4]}}}}"#,
        );
        assert!(clean.is_empty(), "{clean:?}");
    }

    #[test]
    fn no_comm_multi_cell_is_w012() {
        let d = check(
            r#"{"matrix": {"name": "chip", "dims": [2],
                "fill": {"point": {"name": "core", "kind": "compute",
                                   "systolic": [4, 4]}}}}"#,
        );
        assert!(d.iter().any(|x| x.code == diag::W012_UNREACHABLE), "{d:?}");
        // A single-cell matrix needs no comm point.
        let solo = check(
            r#"{"matrix": {"name": "chip", "dims": [1],
                "fill": {"point": {"name": "core", "kind": "compute",
                                   "systolic": [4, 4]}}}}"#,
        );
        assert!(solo.is_empty(), "{solo:?}");
    }

    #[test]
    fn zero_resources_are_w013() {
        let d = check(
            r#"{"matrix": {"name": "chip", "dims": [1],
                "fill": {"point": {"name": "sram", "kind": "memory",
                                   "capacity": 0, "bandwidth": 0}}}}"#,
        );
        assert_eq!(d.iter().filter(|x| x.code == diag::W013_ZERO_RESOURCE).count(), 2);
        assert!(d.iter().all(|x| x.severity == Severity::Warning));
    }

    #[test]
    fn empty_sync_group_is_w014() {
        let d = check(
            r#"{"matrix": {"name": "chip", "dims": [2],
                "comms": [{"name": "noc", "topology": "mesh", "link_bandwidth": 32}],
                "cells": [{"at": [0], "point": {"name": "core", "kind": "compute",
                                                "systolic": [4, 4]}}],
                "sync_groups": [{"name": "ghost", "members": [[1]]}]}}"#,
        );
        assert!(d.iter().any(|x| x.code == diag::W014_EMPTY_SYNC_GROUP), "{d:?}");
    }
}
