//! Minimal error-handling substrate (offline substitute for `anyhow`).
//!
//! [`Error`] is a context chain of human-readable messages: constructing one
//! from any `std::error::Error` captures its whole `source()` chain, and the
//! [`Context`] extension trait prepends higher-level context the way
//! `anyhow::Context` does. `{err}` prints the outermost message; `{err:#}`
//! prints the full chain separated by `": "`.
//!
//! The crate-root macros [`crate::format_err!`], [`crate::bail!`] and
//! [`crate::ensure!`] mirror their `anyhow` namesakes.

use std::fmt;

/// A chain of error messages, outermost context first.
pub struct Error {
    chain: Vec<String>,
}

/// Crate-standard result type (defaults the error to [`Error`]).
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Construct from a single message.
    pub fn msg(msg: impl fmt::Display) -> Error {
        Error {
            chain: vec![msg.to_string()],
        }
    }

    /// Prepend one level of context.
    pub fn wrap(mut self, msg: impl fmt::Display) -> Error {
        self.chain.insert(0, msg.to_string());
        self
    }

    /// The message chain, outermost first.
    pub fn chain(&self) -> &[String] {
        &self.chain
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

// Like `anyhow::Error`, `Error` deliberately does NOT implement
// `std::error::Error`, which keeps this blanket conversion coherent with
// the reflexive `From<Error> for Error`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Context`-style extension for results and options.
pub trait Context<T> {
    /// Attach a fixed context message.
    fn context(self, msg: impl fmt::Display) -> Result<T>;

    /// Attach a lazily computed context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.map_err(|e| e.into().wrap(msg))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.ok_or_else(|| Error::msg(msg))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`](crate::util::error::Error) from a format string.
#[macro_export]
macro_rules! format_err {
    ($($t:tt)*) => { $crate::util::error::Error::msg(format!($($t)*)) };
}

/// Return early with a formatted [`Error`](crate::util::error::Error).
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => { return Err($crate::format_err!($($t)*).into()) };
}

/// Return early with an error unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            $crate::bail!($($t)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn display_outer_and_alternate_chain() {
        let e = Error::msg("inner").wrap("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: inner");
        assert_eq!(e.chain(), ["outer", "inner"]);
    }

    #[test]
    fn from_std_error_captures_sources() {
        let e: Error = io_err().into();
        assert_eq!(format!("{e}"), "missing file");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("loading spec").unwrap_err();
        assert_eq!(format!("{e:#}"), "loading spec: missing file");

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("index {} missing", 3)).unwrap_err();
        assert_eq!(format!("{e}"), "index 3 missing");
        assert_eq!(Some(5).context("present").unwrap(), 5);
    }

    #[test]
    fn macros_build_errors() {
        fn inner(fail: bool) -> Result<u32> {
            crate::ensure!(!fail, "failed with code {}", 7);
            Ok(1)
        }
        assert_eq!(inner(false).unwrap(), 1);
        let e = inner(true).unwrap_err();
        assert_eq!(format!("{e}"), "failed with code 7");
        let e = crate::format_err!("x = {}", 2);
        assert_eq!(format!("{e}"), "x = 2");
    }
}
