//! Three-tier design-space exploration (paper §7): architecture-level
//! (template choice), hardware-parameter (sweeps under area budgets), and
//! mapping (primitive-based search).
//!
//! The module is layered bottom-up:
//!
//! * [`parallel`] — the order-preserving worker machinery every sweep and
//!   search runs on: the persistent streaming [`parallel::WorkerPool`]
//!   plus the one-shot [`parallel::run_parallel`] wrapper.
//! * [`report`] — result tables (console / CSV / JSON).
//! * [`explore`] — the first-class exploration API: [`explore::DesignSpace`]
//!   (typed axes over arch templates, hardware parameters and mapping
//!   knobs) with the composition algebra ([`explore::ProductSpace`] /
//!   [`explore::NestedSpace`]) and the mapping-program space
//!   ([`explore::ProgramSpace`]); [`explore::Objective`] (makespan, EDP,
//!   area-constrained makespan, cost); [`explore::Explorer`] (grid /
//!   random / hill-climb / simulated annealing, optionally tier-aware)
//!   and the batched, memoized evaluation [`explore::Engine`] producing
//!   [`explore::ExplorationReport`]s. (The former `search` module's
//!   greedy tiling lives on as
//!   [`explore::ProgramSpace::greedy_tiling`].)
//! * [`experiments`] — every table and figure of the paper's evaluation;
//!   the grid sweeps, the mapping search and the joint `three-tier`
//!   search run through [`explore`].

pub mod experiments;
pub mod explore;
pub mod parallel;
pub mod report;

pub use experiments::Ctx;
pub use parallel::{
    default_workers, resolve_workers, run_parallel, run_parallel_try, JobOutcome, WorkerPool,
};
pub use report::{fmt, Table};
