//! Design spaces: typed axes, candidate encoding, the [`DesignSpace`]
//! trait, and the built-in parametric spaces over the architecture
//! templates.
//!
//! A candidate is a digit vector — one digit per axis, each digit an index
//! into that axis's value list. This makes every space uniformly
//! enumerable (mixed-radix decode), samplable (uniform digit draws),
//! perturbable (±1 digit moves for the local searchers) and memoizable
//! (the digits are the fingerprint).

use crate::arch::{DmcParams, GsmParams, MpmcParams};
use crate::cost::{AreaModel, CostModel, Packaging};
use crate::hwir::{Hardware, PointId};
use crate::mapping::Mapping;
use crate::taskgraph::{ComputeCost, OpClass, TaskGraph, TaskId, TaskKind};
use crate::util::error::Result;
use crate::util::json::Json;
use crate::workloads::{dmc_prefill, gsm_prefill, mpmc_decode_spatial, LlmConfig, Workload};

use super::super::report::fmt;
use super::objective::{CostUsd, Edp, Makespan, Objective};

// ======================================================================
// Axes and candidates
// ======================================================================

/// Which DSE tier an axis explores (paper §7): architecture template
/// choice, hardware parameter, or mapping decision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AxisKind {
    Arch,
    HwParam,
    Mapping,
}

impl AxisKind {
    pub fn name(&self) -> &'static str {
        match self {
            AxisKind::Arch => "arch",
            AxisKind::HwParam => "hw-param",
            AxisKind::Mapping => "mapping",
        }
    }
}

/// The value list of one axis.
#[derive(Debug, Clone)]
pub enum AxisValues {
    F64(Vec<f64>),
    U64(Vec<u64>),
    /// Categorical values (template names, packaging technologies, …).
    Tag(Vec<String>),
    /// `n` index-labeled values `0..n` — a compact encoding for axes
    /// whose values are positions in some external list (e.g. compute
    /// points of a placement space), avoiding per-axis label storage.
    Count(usize),
}

impl AxisValues {
    pub fn len(&self) -> usize {
        match self {
            AxisValues::F64(v) => v.len(),
            AxisValues::U64(v) => v.len(),
            AxisValues::Tag(v) => v.len(),
            AxisValues::Count(n) => *n,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Numeric view of value `i` (categorical values map to their index).
    pub fn num(&self, i: usize) -> f64 {
        match self {
            AxisValues::F64(v) => v[i],
            AxisValues::U64(v) => v[i] as f64,
            AxisValues::Tag(_) | AxisValues::Count(_) => i as f64,
        }
    }

    /// Human-readable label of value `i`.
    pub fn label(&self, i: usize) -> String {
        match self {
            AxisValues::F64(v) => fmt(v[i]),
            AxisValues::U64(v) => v[i].to_string(),
            AxisValues::Tag(v) => v[i].clone(),
            AxisValues::Count(_) => i.to_string(),
        }
    }
}

/// A typed axis descriptor: name, DSE tier, and candidate values.
#[derive(Debug, Clone)]
pub struct Axis {
    pub name: String,
    pub kind: AxisKind,
    pub values: AxisValues,
}

impl Axis {
    pub fn f64s(name: impl Into<String>, kind: AxisKind, values: &[f64]) -> Axis {
        Axis {
            name: name.into(),
            kind,
            values: AxisValues::F64(values.to_vec()),
        }
    }

    pub fn u64s(name: impl Into<String>, kind: AxisKind, values: &[u64]) -> Axis {
        Axis {
            name: name.into(),
            kind,
            values: AxisValues::U64(values.to_vec()),
        }
    }

    pub fn tags(name: impl Into<String>, kind: AxisKind, values: Vec<String>) -> Axis {
        Axis {
            name: name.into(),
            kind,
            values: AxisValues::Tag(values),
        }
    }

    /// An axis of `n` index-labeled values `0..n`.
    pub fn count(name: impl Into<String>, kind: AxisKind, n: usize) -> Axis {
        Axis {
            name: name.into(),
            kind,
            values: AxisValues::Count(n),
        }
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }
}

/// One point of a design space: a digit per axis, each digit indexing
/// into the axis's value list. The digits double as the memo-cache
/// fingerprint.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Candidate(pub Vec<u32>);

impl Candidate {
    /// FNV-1a fingerprint of the digit vector (stable across runs).
    pub fn fingerprint(&self) -> u64 {
        let mut h: u64 = 0xcbf29ce484222325;
        for d in &self.0 {
            for b in d.to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(0x100000001b3);
            }
        }
        h
    }
}

/// A materialized candidate: a ready-to-simulate workload plus the
/// side-channel figures (area, manufacturing cost) that the non-makespan
/// objectives consume.
#[derive(Debug, Clone)]
pub struct Design {
    pub workload: Workload,
    /// Chip/system silicon area, when the space computes one.
    pub area_mm2: Option<f64>,
    /// Manufacturing cost in dollars, when the space computes one.
    pub cost_usd: Option<f64>,
}

impl Design {
    pub fn new(workload: Workload) -> Design {
        Design {
            workload,
            area_mm2: None,
            cost_usd: None,
        }
    }

    /// Borrowed view for objective scoring.
    pub fn view(&self) -> DesignView<'_> {
        DesignView {
            hw: &self.workload.hw,
            graph: &self.workload.graph,
            mapping: &self.workload.mapping,
            area_mm2: self.area_mm2,
            cost_usd: self.cost_usd,
        }
    }
}

/// A borrowed view of one evaluated design, as seen by [`Objective`]s.
///
/// Objectives used to take the owned [`Design`]; with topology-keyed setup
/// reuse the hardware model and task-graph skeleton live once in a shared
/// `Arc` per topology and only the mapping is per-candidate, so scoring
/// receives borrows instead of forcing a per-candidate rebuild.
#[derive(Debug, Clone, Copy)]
pub struct DesignView<'a> {
    pub hw: &'a Hardware,
    pub graph: &'a TaskGraph,
    pub mapping: &'a Mapping,
    pub area_mm2: Option<f64>,
    pub cost_usd: Option<f64>,
}

/// The per-candidate half of a topology-shared evaluation: everything
/// [`DesignSpace::materialize`] produces *except* the hardware model and
/// the task-graph skeleton (which candidates sharing a
/// [`DesignSpace::topology_key`] reuse from a cached setup).
#[derive(Debug)]
pub struct Binding {
    pub mapping: Mapping,
    pub area_mm2: Option<f64>,
    pub cost_usd: Option<f64>,
}

impl Binding {
    /// Decompose a full materialization into its per-candidate binding.
    pub fn of(design: Design) -> Binding {
        Binding {
            mapping: design.workload.mapping,
            area_mm2: design.area_mm2,
            cost_usd: design.cost_usd,
        }
    }
}

// ======================================================================
// The DesignSpace trait
// ======================================================================

/// An enumerable/samplable candidate set over typed axes.
///
/// Implementors provide the axes and `materialize`; enumeration, random
/// access, labeling, bounds checking and neighbor generation all come for
/// free from the digit encoding.
pub trait DesignSpace: Sync {
    fn name(&self) -> &str;

    /// The typed axis descriptors; axis `i` has `axes()[i].len()` values.
    fn axes(&self) -> &[Axis];

    /// Decode a candidate into a concrete, simulatable design.
    fn materialize(&self, c: &Candidate) -> Result<Design>;

    /// Hardware fingerprint of a candidate: candidates with equal
    /// `Some(key)`s share one evaluation setup — hardware model,
    /// task-graph skeleton, interned route table and simulator arenas are
    /// built once per distinct key and reused across the whole search.
    ///
    /// The default, `None`, means "every candidate is its own topology"
    /// (no sharing — always correct, and free: nothing is allocated or
    /// retained). Spaces that only perturb mapping-tier axes on a fixed
    /// topology (e.g. [`PlacementSpace`]) override this with the subset
    /// of digits that actually changes the hardware — often the empty
    /// vector, meaning one setup for the whole space. Contract: all
    /// candidates sharing a key must materialize the same hardware, the
    /// same graph skeleton, and the same placement for every routed
    /// communication task, and [`DesignSpace::bind`] must agree with
    /// [`DesignSpace::materialize`] on the per-candidate mapping.
    fn topology_key(&self, c: &Candidate) -> Option<Vec<u32>> {
        let _ = c;
        None
    }

    /// The per-candidate half of an evaluation against a shared setup:
    /// the mapping plus side figures, *without* rebuilding hardware or
    /// graph. The default decomposes a full [`DesignSpace::materialize`]
    /// (correct for any space); spaces that coarsen
    /// [`DesignSpace::topology_key`] should override it with a cheap
    /// mapping-only path.
    fn bind(&self, c: &Candidate) -> Result<Binding> {
        Ok(Binding::of(self.materialize(c)?))
    }

    /// Total number of candidates (product of axis cardinalities).
    fn size(&self) -> u64 {
        self.axes()
            .iter()
            .fold(1u64, |acc, a| acc.saturating_mul(a.len() as u64))
    }

    /// The `i`-th candidate in lexicographic order (last axis fastest).
    fn nth(&self, mut i: u64) -> Candidate {
        let axes = self.axes();
        let mut digits = vec![0u32; axes.len()];
        for k in (0..axes.len()).rev() {
            let card = axes[k].len().max(1) as u64;
            digits[k] = (i % card) as u32;
            i /= card;
        }
        Candidate(digits)
    }

    /// The search starting point (all-zeros unless the space has a
    /// distinguished baseline, e.g. an existing placement).
    fn initial(&self) -> Candidate {
        Candidate(vec![0; self.axes().len()])
    }

    fn in_bounds(&self, c: &Candidate) -> bool {
        c.0.len() == self.axes().len()
            && c.0
                .iter()
                .zip(self.axes())
                .all(|(d, a)| (*d as usize) < a.len())
    }

    /// Single-digit ±1 perturbations, in axis order (the move set of the
    /// local searchers).
    fn neighbors(&self, c: &Candidate) -> Vec<Candidate> {
        let mut out = Vec::new();
        for (k, a) in self.axes().iter().enumerate() {
            let d = c.0[k];
            if d > 0 {
                let mut n = c.clone();
                n.0[k] = d - 1;
                out.push(n);
            }
            if (d as usize) + 1 < a.len() {
                let mut n = c.clone();
                n.0[k] = d + 1;
                out.push(n);
            }
        }
        out
    }

    /// `axis=value` rendering of a candidate.
    fn label(&self, c: &Candidate) -> String {
        self.axes()
            .iter()
            .zip(&c.0)
            .map(|(a, d)| format!("{}={}", a.name, a.values.label(*d as usize)))
            .collect::<Vec<_>>()
            .join(" ")
    }

    /// Product composition hook: apply this space's candidate as a
    /// *refinement* of a design some other space materialized (see
    /// [`ProductSpace`](super::compose::ProductSpace) — its first
    /// sub-space materializes, every later sub refines). Spaces that
    /// transform an existing workload (e.g.
    /// [`ProgramSpace`](super::program::ProgramSpace)) override this; the
    /// default declines.
    fn refine(&self, base: Design, c: &Candidate) -> Result<Design> {
        let _ = (base, c);
        crate::bail!(
            "space '{}' cannot refine an existing design (only program-style \
             spaces compose as non-leading product subs)",
            self.name()
        )
    }

    /// Structural fingerprint of the space: FNV-1a over the name and
    /// every axis's name, tier and value labels. Stable across runs and
    /// processes (no addresses, no hash-map iteration), so it identifies
    /// a space in serialized artifacts — exploration checkpoints refuse
    /// to resume against a space with a different fingerprint, and the
    /// serve daemon keys its process-wide plan/memo stores on it.
    ///
    /// Composed spaces inherit it: their axes *are* their structure.
    fn fingerprint(&self) -> u64 {
        fn eat(h: &mut u64, bytes: &[u8]) {
            for b in bytes {
                *h ^= *b as u64;
                *h = h.wrapping_mul(0x100000001b3);
            }
        }
        let mut h: u64 = 0xcbf29ce484222325;
        eat(&mut h, self.name().as_bytes());
        for a in self.axes() {
            eat(&mut h, &[0x1f]);
            eat(&mut h, a.name.as_bytes());
            eat(&mut h, a.kind.name().as_bytes());
            eat(&mut h, &(a.len() as u64).to_le_bytes());
            for i in 0..a.len() {
                eat(&mut h, &[0x1e]);
                eat(&mut h, a.values.label(i).as_bytes());
            }
        }
        h
    }
}

// ======================================================================
// Parametric spaces over the architecture templates
// ======================================================================

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum ArchKind {
    Dmc,
    Gsm,
}

const DMC_AXES: &[&str] = &["cfg", "lmem_bw", "noc_bw", "lmem_lat"];
const GSM_AXES: &[&str] = &["cfg", "l2_bw", "l1_bw", "l2_lat"];

/// Hardware-parameter space over the DMC or GSM template: a `cfg` axis
/// selects the Table-2 baseline, and bandwidth/latency axes are applied
/// through the fixed-area transform (`with_fixed_area`). Buildable in code
/// or from a JSON description (`mldse explore --space FILE.json`).
pub struct ParamSpace {
    name: String,
    arch: ArchKind,
    axes: Vec<Axis>,
    llm: LlmConfig,
    seq: u32,
    dmc_grid: (usize, usize),
    gsm_sms: usize,
    area: AreaModel,
}

impl ParamSpace {
    fn base(name: &str, arch: ArchKind, quick: bool) -> ParamSpace {
        let llm = if quick {
            LlmConfig {
                hidden: 512,
                heads: 8,
                ffn: 2048,
                layers: 8,
                elem_bytes: 2,
            }
        } else {
            LlmConfig::gpt3_6_7b()
        };
        ParamSpace {
            name: name.to_string(),
            arch,
            axes: Vec::new(),
            llm,
            seq: if quick { 256 } else { 2048 },
            dmc_grid: if quick { (4, 4) } else { (16, 8) },
            gsm_sms: if quick { 16 } else { 128 },
            area: AreaModel::default(),
        }
    }

    /// A DMC-template space with no axes yet (`quick` shrinks the model,
    /// sequence length and chip grid to CI sizes).
    pub fn dmc(name: &str, quick: bool) -> ParamSpace {
        ParamSpace::base(name, ArchKind::Dmc, quick)
    }

    /// A GSM-template space with no axes yet.
    pub fn gsm(name: &str, quick: bool) -> ParamSpace {
        ParamSpace::base(name, ArchKind::Gsm, quick)
    }

    fn valid_axes(&self) -> &'static [&'static str] {
        match self.arch {
            ArchKind::Dmc => DMC_AXES,
            ArchKind::Gsm => GSM_AXES,
        }
    }

    /// Add an axis by parameter name; errors on names the template does
    /// not expose.
    pub fn axis(mut self, name: &str, values: &[f64]) -> Result<ParamSpace> {
        crate::ensure!(
            self.valid_axes().contains(&name),
            "unknown axis '{name}' for {} space (valid: {})",
            match self.arch {
                ArchKind::Dmc => "dmc",
                ArchKind::Gsm => "gsm",
            },
            self.valid_axes().join(", ")
        );
        crate::ensure!(!values.is_empty(), "axis '{name}' has no values");
        if name == "cfg" {
            for v in values {
                crate::ensure!(
                    (1.0..=4.0).contains(v) && v.fract() == 0.0,
                    "axis 'cfg' values must be integers 1..=4 (got {v})"
                );
            }
        }
        let kind = if name == "cfg" {
            AxisKind::Arch
        } else {
            AxisKind::HwParam
        };
        let axis = if name == "cfg" || name.ends_with("_lat") {
            Axis::u64s(name, kind, &values.iter().map(|v| *v as u64).collect::<Vec<_>>())
        } else {
            Axis::f64s(name, kind, values)
        };
        self.axes.push(axis);
        Ok(self)
    }

    /// Override the sequence length.
    pub fn seq(mut self, seq: u32) -> ParamSpace {
        self.seq = seq;
        self
    }

    /// Parse a space description:
    ///
    /// `{"name": "...", "arch": "dmc"|"gsm", "quick": bool, "seq": n,
    ///   "axes": {"cfg": [1,2], "lmem_bw": [76, 152], ...}}`
    pub fn from_json(text: &str) -> Result<ParamSpace> {
        let doc = Json::parse(text)?;
        ParamSpace::from_json_value(&doc)
    }

    /// Parse from an already-parsed JSON value (the `"type": "param"`
    /// arm of composed space files).
    pub fn from_json_value(doc: &Json) -> Result<ParamSpace> {
        let name = doc
            .get("name")
            .and_then(|v| v.as_str())
            .unwrap_or("custom-space")
            .to_string();
        let arch = match doc.get("arch").and_then(|v| v.as_str()) {
            Some("dmc") => ArchKind::Dmc,
            Some("gsm") => ArchKind::Gsm,
            Some(other) => crate::bail!("unknown arch '{other}' (valid: dmc, gsm)"),
            None => crate::bail!("space file needs an \"arch\" field (dmc or gsm)"),
        };
        let quick = doc
            .get("quick")
            .and_then(|v| v.as_bool())
            .unwrap_or(false);
        let mut space = ParamSpace::base(&name, arch, quick);
        if let Some(seq) = doc.get("seq").and_then(|v| v.as_u64()) {
            space.seq = seq as u32;
        }
        let axes = doc
            .get("axes")
            .and_then(|v| v.as_obj())
            .ok_or_else(|| crate::format_err!("space file needs an \"axes\" object"))?;
        for (axis_name, values) in axes.iter() {
            let arr = values
                .as_arr()
                .ok_or_else(|| crate::format_err!("axis '{axis_name}' must be an array"))?;
            let mut nums = Vec::with_capacity(arr.len());
            for v in arr {
                nums.push(v.as_f64().ok_or_else(|| {
                    crate::format_err!("axis '{axis_name}' has a non-numeric value")
                })?);
            }
            space = space.axis(axis_name, &nums)?;
        }
        crate::ensure!(!space.axes.is_empty(), "space '{name}' defines no axes");
        Ok(space)
    }

    /// Resolved template parameters for a candidate (no hardware build).
    /// Errors on out-of-range `cfg` values (user-supplied space files).
    fn dmc_params(&self, c: &Candidate) -> Result<DmcParams> {
        let mut cfg_idx = 2usize;
        let mut lmem_bw = None;
        let mut noc_bw = None;
        let mut lmem_lat = None;
        for (a, d) in self.axes.iter().zip(&c.0) {
            let v = a.values.num(*d as usize);
            match a.name.as_str() {
                "cfg" => cfg_idx = v as usize,
                "lmem_bw" => lmem_bw = Some(v),
                "noc_bw" => noc_bw = Some(v),
                "lmem_lat" => lmem_lat = Some(v as u64),
                _ => {}
            }
        }
        let mut base = DmcParams::table2(cfg_idx)?;
        base.grid = self.dmc_grid;
        Ok(base.with_fixed_area(
            lmem_bw.unwrap_or(base.lmem_bandwidth),
            noc_bw.unwrap_or(base.noc_bandwidth),
            lmem_lat.unwrap_or(base.lmem_latency),
            &self.area,
        ))
    }

    fn gsm_params(&self, c: &Candidate) -> Result<GsmParams> {
        let mut cfg_idx = 2usize;
        let mut l2_bw = None;
        let mut l1_bw = None;
        let mut l2_lat = None;
        for (a, d) in self.axes.iter().zip(&c.0) {
            let v = a.values.num(*d as usize);
            match a.name.as_str() {
                "cfg" => cfg_idx = v as usize,
                "l2_bw" => l2_bw = Some(v),
                "l1_bw" => l1_bw = Some(v),
                "l2_lat" => l2_lat = Some(v as u64),
                _ => {}
            }
        }
        let mut base = GsmParams::table2(cfg_idx)?;
        base.sms = self.gsm_sms;
        Ok(base.with_fixed_area(
            l2_bw.unwrap_or(base.l2_bandwidth),
            l1_bw.unwrap_or(base.l1_bandwidth),
            l2_lat.unwrap_or(base.l2_latency),
            &self.area,
        ))
    }
}

impl DesignSpace for ParamSpace {
    fn name(&self) -> &str {
        &self.name
    }

    fn axes(&self) -> &[Axis] {
        &self.axes
    }

    fn materialize(&self, c: &Candidate) -> Result<Design> {
        crate::ensure!(self.in_bounds(c), "candidate out of bounds for '{}'", self.name);
        match self.arch {
            ArchKind::Dmc => {
                let p = self.dmc_params(c)?;
                let mut d = Design::new(dmc_prefill(&self.llm, self.seq, &p));
                d.area_mm2 = Some(p.area(&self.area).3);
                Ok(d)
            }
            ArchKind::Gsm => {
                let p = self.gsm_params(c)?;
                let mut d = Design::new(gsm_prefill(&self.llm, self.seq, &p));
                d.area_mm2 = Some(p.area(&self.area).3);
                Ok(d)
            }
        }
    }
}

// ======================================================================
// Packaging space (MPMC-DMC, Fig. 10 trade-off)
// ======================================================================

/// Performance/cost space over the MPMC-DMC spatial-computing system:
/// packaging technology × chiplets-per-package, with manufacturing cost
/// attached to each design for the [`CostUsd`] objective.
pub struct PackagingSpace {
    name: String,
    llm: LlmConfig,
    pos: u32,
    layers: u32,
    /// Quick-mode shrink: (chiplet grid, total chiplet pool).
    shrink: Option<((usize, usize), usize)>,
    axes: Vec<Axis>,
    area: AreaModel,
    cost: CostModel,
}

impl PackagingSpace {
    pub fn new(
        name: &str,
        llm: LlmConfig,
        pos: u32,
        layers: u32,
        cpps: &[usize],
        shrink: Option<((usize, usize), usize)>,
    ) -> PackagingSpace {
        let axes = vec![
            Axis::tags(
                "packaging",
                AxisKind::Arch,
                vec!["MCM".to_string(), "2.5D".to_string()],
            ),
            Axis::u64s(
                "cpp",
                AxisKind::HwParam,
                &cpps.iter().map(|c| *c as u64).collect::<Vec<_>>(),
            ),
        ];
        PackagingSpace {
            name: name.to_string(),
            llm,
            pos,
            layers,
            shrink,
            axes,
            area: AreaModel::default(),
            cost: CostModel::default(),
        }
    }

    /// The paper-preset instance behind the `packaging`/`packaging-quick`
    /// presets and the `"type": "packaging"` space files (and the outer
    /// tier of the `three-tier` composed space).
    pub fn paper_preset(name: &str, quick: bool) -> PackagingSpace {
        if quick {
            let llm = LlmConfig {
                hidden: 512,
                heads: 8,
                ffn: 2048,
                layers: 8,
                elem_bytes: 2,
            };
            PackagingSpace::new(name, llm, 256, 2, &[1, 2], Some(((4, 4), 6)))
        } else {
            PackagingSpace::new(name, LlmConfig::gpt3_6_7b(), 2048, 8, &[1, 2, 3, 4, 6], None)
        }
    }

    /// Append a chiplet local-memory bandwidth axis (hw-param tier): the
    /// value overrides `MpmcParams::chiplet.lmem_bandwidth`.
    pub fn with_lmem_bw_axis(mut self, values: &[f64]) -> PackagingSpace {
        self.axes
            .push(Axis::f64s("lmem_bw", AxisKind::HwParam, values));
        self
    }

    /// Parse a `{"type": "packaging"}` space file value:
    ///
    /// `{"name": "...", "quick": bool, "pos": n, "layers": n,
    ///   "cpp": [1, 2, ...], "lmem_bw": [76, 304]}`
    ///
    /// Missing fields default to [`PackagingSpace::paper_preset`] at the
    /// given `quick` setting.
    pub fn from_json_value(doc: &Json) -> Result<PackagingSpace> {
        let name = doc
            .get("name")
            .and_then(|v| v.as_str())
            .unwrap_or("packaging")
            .to_string();
        let quick = doc.get("quick").and_then(|v| v.as_bool()).unwrap_or(false);
        let mut space = PackagingSpace::paper_preset(&name, quick);
        if let Some(pos) = doc.get("pos").and_then(|v| v.as_u64()) {
            space.pos = pos as u32;
        }
        if let Some(layers) = doc.get("layers").and_then(|v| v.as_u64()) {
            space.layers = layers as u32;
        }
        if let Some(cpps) = doc.get("cpp") {
            let arr = cpps
                .as_arr()
                .ok_or_else(|| crate::format_err!("\"cpp\" must be an array"))?;
            let mut vals = Vec::with_capacity(arr.len());
            for v in arr {
                let cpp = v
                    .as_u64()
                    .ok_or_else(|| crate::format_err!("\"cpp\" has a non-integer value"))?;
                crate::ensure!(cpp >= 1, "\"cpp\" values must be >= 1 (got {cpp})");
                vals.push(cpp);
            }
            crate::ensure!(!vals.is_empty(), "\"cpp\" must not be empty");
            space.axes[1] = Axis::u64s("cpp", AxisKind::HwParam, &vals);
        }
        if let Some(bws) = doc.get("lmem_bw") {
            let arr = bws
                .as_arr()
                .ok_or_else(|| crate::format_err!("\"lmem_bw\" must be an array"))?;
            let mut vals = Vec::with_capacity(arr.len());
            for v in arr {
                vals.push(v.as_f64().ok_or_else(|| {
                    crate::format_err!("\"lmem_bw\" has a non-numeric value")
                })?);
            }
            crate::ensure!(!vals.is_empty(), "\"lmem_bw\" must not be empty");
            space = space.with_lmem_bw_axis(&vals);
        }
        Ok(space)
    }

    /// (packaging, chiplets/package) of a candidate.
    pub fn describe(&self, c: &Candidate) -> (Packaging, usize) {
        let pkg = if c.0[0] == 0 {
            Packaging::Mcm
        } else {
            Packaging::Interposer2_5D
        };
        let cpp = self.axes[1].values.num(c.0[1] as usize) as usize;
        (pkg, cpp)
    }

    fn params(&self, c: &Candidate) -> Result<MpmcParams> {
        let (pkg, cpp) = self.describe(c);
        let mut p = MpmcParams::paper(cpp, pkg);
        if let Some((grid, total)) = self.shrink {
            p.total_chiplets = total;
            p.chiplet.grid = grid;
        }
        // optional appended hw-param axes (axis index 2+)
        for (a, d) in self.axes.iter().zip(&c.0).skip(2) {
            if a.name == "lmem_bw" {
                p.chiplet.lmem_bandwidth = a.values.num(*d as usize);
            }
        }
        crate::ensure!(
            p.total_chiplets % p.chiplets_per_package == 0,
            "{} chiplets not divisible into packages of {cpp}",
            p.total_chiplets
        );
        crate::ensure!(
            p.total_chiplets >= 3 * self.layers as usize,
            "spatial decode needs 3 chiplets per layer"
        );
        Ok(p)
    }
}

impl DesignSpace for PackagingSpace {
    fn name(&self) -> &str {
        &self.name
    }

    fn axes(&self) -> &[Axis] {
        &self.axes
    }

    fn materialize(&self, c: &Candidate) -> Result<Design> {
        crate::ensure!(self.in_bounds(c), "candidate out of bounds for '{}'", self.name);
        let p = self.params(c)?;
        let mut d = Design::new(mpmc_decode_spatial(&self.llm, self.pos, self.layers, &p));
        d.cost_usd = Some(p.system_cost(&self.area, &self.cost));
        d.area_mm2 = Some(p.chiplet.area(&self.area).3 * p.total_chiplets as f64);
        Ok(d)
    }
}

// ======================================================================
// Placement space (mapping tier)
// ======================================================================

/// Mapping-tier space: one axis per movable (enabled compute) task, whose
/// values are the hardware's compute points. The baseline mapping supplies
/// the initial candidate; non-movable tasks keep their base placement.
pub struct PlacementSpace {
    name: String,
    hw: Hardware,
    graph: TaskGraph,
    base: Mapping,
    movable: Vec<TaskId>,
    points: Vec<PointId>,
    initial: Vec<u32>,
    axes: Vec<Axis>,
}

impl PlacementSpace {
    pub fn new(name: &str, hw: Hardware, graph: TaskGraph, base: Mapping) -> PlacementSpace {
        let movable: Vec<TaskId> = graph
            .iter()
            .filter(|t| t.enabled && t.kind.is_compute())
            .map(|t| t.id)
            .collect();
        let points = hw.points_of_kind("compute");
        let initial: Vec<u32> = movable
            .iter()
            .map(|t| {
                base.point_of(*t)
                    .and_then(|p| points.iter().position(|q| *q == p))
                    .unwrap_or(0) as u32
            })
            .collect();
        // one compact index axis per task (values = compute-point indices)
        let axes: Vec<Axis> = movable
            .iter()
            .map(|t| Axis::count(graph.task(*t).name.clone(), AxisKind::Mapping, points.len()))
            .collect();
        PlacementSpace {
            name: name.to_string(),
            hw,
            graph,
            base,
            movable,
            points,
            initial,
            axes,
        }
    }

    /// Write a candidate's placement into an external mapping (used by the
    /// annealing-placement flow to update the caller's state).
    pub fn apply(&self, c: &Candidate, mapping: &mut Mapping) {
        for (i, t) in self.movable.iter().enumerate() {
            mapping.map(*t, self.points[c.0[i] as usize]);
        }
    }
}

impl DesignSpace for PlacementSpace {
    fn name(&self) -> &str {
        &self.name
    }

    fn axes(&self) -> &[Axis] {
        &self.axes
    }

    fn initial(&self) -> Candidate {
        Candidate(self.initial.clone())
    }

    fn materialize(&self, c: &Candidate) -> Result<Design> {
        crate::ensure!(self.in_bounds(c), "candidate out of bounds for '{}'", self.name);
        let mut mapping = self.base.clone();
        self.apply(c, &mut mapping);
        Ok(Design::new(Workload {
            hw: self.hw.clone(),
            graph: self.graph.clone(),
            mapping,
            name: self.name.clone(),
            notes: Vec::new(),
        }))
    }

    /// Every candidate shares one topology: only compute-task placement
    /// moves, so the hardware, the graph and every routed communication
    /// task's placement are fixed across the space.
    fn topology_key(&self, _c: &Candidate) -> Option<Vec<u32>> {
        Some(Vec::new())
    }

    /// Mapping-only rebinding: no hardware/graph clone per candidate.
    fn bind(&self, c: &Candidate) -> Result<Binding> {
        crate::ensure!(self.in_bounds(c), "candidate out of bounds for '{}'", self.name);
        let mut mapping = self.base.clone();
        self.apply(c, &mut mapping);
        Ok(Binding {
            mapping,
            area_mm2: None,
            cost_usd: None,
        })
    }
}

// ======================================================================
// Presets
// ======================================================================

/// Names accepted by [`preset`].
pub fn preset_names() -> &'static [&'static str] {
    &[
        "dmc",
        "dmc-quick",
        "dmc-area",
        "gsm",
        "gsm-quick",
        "packaging",
        "packaging-quick",
        "mapping",
        "three-tier",
        "three-tier-quick",
    ]
}

fn dmc_preset(name: &str, quick: bool) -> Result<ParamSpace> {
    let (lmem, noc, lat): (&[f64], &[f64], &[f64]) = if quick {
        (&[64.0, 304.0], &[16.0, 64.0], &[2.0, 8.0])
    } else {
        (
            &[38.0, 76.0, 152.0, 304.0, 608.0],
            &[8.0, 16.0, 32.0, 64.0, 128.0],
            &[1.0, 2.0, 4.0, 8.0, 16.0],
        )
    };
    ParamSpace::dmc(name, quick)
        .axis("cfg", &[1.0, 2.0, 3.0, 4.0])?
        .axis("lmem_bw", lmem)?
        .axis("noc_bw", noc)?
        .axis("lmem_lat", lat)
}

fn gsm_preset(name: &str, quick: bool) -> Result<ParamSpace> {
    let l2: &[f64] = if quick {
        &[1280.0, 5120.0, 20480.0]
    } else {
        &[640.0, 1280.0, 2560.0, 5120.0, 10240.0, 20480.0]
    };
    ParamSpace::gsm(name, quick)
        .axis("cfg", &[1.0, 2.0, 3.0, 4.0])?
        .axis("l2_bw", l2)
}

/// A small mapping-tier demo problem: `n_tasks` skewed independent compute
/// tasks, all initially on the first core of a DMC chip.
pub fn placement_demo(name: &str, grid: (usize, usize), n_tasks: usize) -> PlacementSpace {
    let params = DmcParams {
        grid,
        with_dram: false,
        ..DmcParams::default()
    };
    let hw = params.build();
    let core0 = hw.points_of_kind("compute")[0];
    let mut graph = TaskGraph::new();
    let mut mapping = Mapping::new();
    for i in 0..n_tasks {
        let mut c = ComputeCost::zero(OpClass::Elementwise);
        c.vec_flops = 40_000.0 * (1 + i % 4) as f64;
        let t = graph.add(format!("t{i}"), TaskKind::Compute(c));
        mapping.map(t, core0);
    }
    PlacementSpace::new(name, hw, graph, mapping)
}

/// Resolve a named preset into a (space, default objectives) pair.
pub fn preset(name: &str) -> Result<(Box<dyn DesignSpace>, Vec<Box<dyn Objective>>)> {
    let perf: Vec<Box<dyn Objective>> = vec![Box::new(Makespan), Box::new(Edp)];
    match name {
        "dmc" => Ok((Box::new(dmc_preset("dmc", false)?), perf)),
        "dmc-quick" => Ok((Box::new(dmc_preset("dmc-quick", true)?), perf)),
        "dmc-area" => {
            let objs: Vec<Box<dyn Objective>> = vec![
                Box::new(super::objective::AreaConstrainedMakespan::new(900.0)),
                Box::new(Edp),
            ];
            Ok((Box::new(dmc_preset("dmc-area", false)?), objs))
        }
        "gsm" => Ok((Box::new(gsm_preset("gsm", false)?), perf)),
        "gsm-quick" => Ok((Box::new(gsm_preset("gsm-quick", true)?), perf)),
        "packaging" | "packaging-quick" => {
            let space = PackagingSpace::paper_preset(name, name.ends_with("-quick"));
            let objs: Vec<Box<dyn Objective>> = vec![Box::new(Makespan), Box::new(CostUsd)];
            Ok((Box::new(space), objs))
        }
        "mapping" => Ok((Box::new(placement_demo("mapping", (2, 2), 8)), perf)),
        "three-tier" | "three-tier-quick" => {
            let space = super::compose::three_tier(name, name.ends_with("-quick"))?;
            let objs: Vec<Box<dyn Objective>> = vec![Box::new(Makespan), Box::new(CostUsd)];
            Ok((Box::new(space), objs))
        }
        other => crate::bail!(
            "unknown preset '{other}' (valid: {})",
            preset_names().join(", ")
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn digit_enumeration_roundtrip() {
        let space = dmc_preset("t", true).unwrap();
        // 4 cfg * 2 lmem * 2 noc * 2 lat
        assert_eq!(space.size(), 32);
        assert_eq!(space.nth(0).0, vec![0, 0, 0, 0]);
        assert_eq!(space.nth(1).0, vec![0, 0, 0, 1]);
        assert_eq!(space.nth(2).0, vec![0, 0, 1, 0]);
        assert_eq!(space.nth(31).0, vec![3, 1, 1, 1]);
        // lexicographic: index i reconstructs from digits
        for i in 0..32u64 {
            let c = space.nth(i);
            let mut j = 0u64;
            for (d, a) in c.0.iter().zip(space.axes()) {
                j = j * a.len() as u64 + *d as u64;
            }
            assert_eq!(i, j);
        }
    }

    #[test]
    fn neighbors_are_single_digit_moves() {
        let space = dmc_preset("t", true).unwrap();
        let c = Candidate(vec![0, 1, 0, 1]);
        let ns = space.neighbors(&c);
        // cfg can go up; lmem down; noc up; lat down
        assert_eq!(ns.len(), 4);
        for n in &ns {
            let diff: u32 = n
                .0
                .iter()
                .zip(&c.0)
                .map(|(a, b)| if a == b { 0 } else { 1 })
                .sum();
            assert_eq!(diff, 1);
            assert!(space.in_bounds(n));
        }
    }

    #[test]
    fn labels_and_kinds() {
        let space = dmc_preset("t", true).unwrap();
        let c = space.nth(0);
        let label = space.label(&c);
        assert!(label.contains("cfg=1"), "{label}");
        assert!(label.contains("lmem_bw=64"), "{label}");
        assert_eq!(space.axes()[0].kind, AxisKind::Arch);
        assert_eq!(space.axes()[1].kind, AxisKind::HwParam);
    }

    #[test]
    fn unknown_axis_rejected_with_valid_list() {
        let err = ParamSpace::dmc("t", true).axis("l2_bw", &[1.0]).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("unknown axis"), "{msg}");
        assert!(msg.contains("lmem_bw"), "{msg}");
    }

    #[test]
    fn out_of_range_cfg_is_an_error_not_a_panic() {
        // user-supplied space files with bad table2 configs must surface
        // as CLI errors, both at parse time and at materialization
        let err = ParamSpace::from_json(
            r#"{"arch": "dmc", "quick": true, "axes": {"cfg": [9]}}"#,
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("cfg"), "{err:#}");
        let err = ParamSpace::from_json(
            r#"{"arch": "gsm", "quick": true, "axes": {"cfg": [0]}}"#,
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("cfg"), "{err:#}");
    }

    #[test]
    fn json_space_parses_and_materializes() {
        let text = r#"{
            "name": "mini",
            "arch": "dmc",
            "quick": true,
            "seq": 128,
            "axes": {"cfg": [2, 3], "lmem_bw": [76, 152]}
        }"#;
        let space = ParamSpace::from_json(text).unwrap();
        assert_eq!(space.name(), "mini");
        assert_eq!(space.size(), 4);
        let d = space.materialize(&space.nth(0)).unwrap();
        assert!(d.area_mm2.unwrap() > 0.0);
        assert!(d.workload.graph.len() > 0);
    }

    #[test]
    fn json_space_errors() {
        assert!(ParamSpace::from_json("{}").is_err());
        assert!(ParamSpace::from_json(r#"{"arch": "tpu", "axes": {}}"#).is_err());
        assert!(
            ParamSpace::from_json(r#"{"arch": "dmc", "axes": {"cfg": ["x"]}}"#).is_err()
        );
        assert!(ParamSpace::from_json(r#"{"arch": "dmc", "axes": {}}"#).is_err());
    }

    #[test]
    fn placement_space_initial_matches_base() {
        let space = placement_demo("demo", (2, 2), 4);
        let init = space.initial();
        assert_eq!(init.0, vec![0, 0, 0, 0]);
        assert_eq!(space.axes().len(), 4);
        assert_eq!(space.size(), 4u64.pow(4));
        let d = space.materialize(&init).unwrap();
        assert_eq!(d.workload.graph.len(), 4);
        // all four tasks on the first compute point
        let p0 = space.points[0];
        assert_eq!(d.workload.mapping.tasks_on(p0).len(), 4);
    }

    #[test]
    fn packaging_space_costs_attached() {
        let llm = LlmConfig {
            hidden: 512,
            heads: 8,
            ffn: 2048,
            layers: 8,
            elem_bytes: 2,
        };
        let space = PackagingSpace::new("pkg", llm, 128, 2, &[1, 2], Some(((2, 2), 6)));
        assert_eq!(space.size(), 4);
        let d = space.materialize(&space.nth(0)).unwrap();
        assert!(d.cost_usd.unwrap() > 0.0);
        let (pkg, cpp) = space.describe(&space.nth(0));
        assert_eq!(pkg, Packaging::Mcm);
        assert_eq!(cpp, 1);
        let (pkg, cpp) = space.describe(&space.nth(3));
        assert_eq!(pkg, Packaging::Interposer2_5D);
        assert_eq!(cpp, 2);
    }

    #[test]
    fn preset_lookup() {
        for name in preset_names() {
            // full-size presets still construct cheaply (no hardware built)
            let (space, objs) = preset(name).unwrap();
            assert!(space.size() > 0, "{name}");
            assert!(objs.len() >= 2, "{name}");
        }
        assert!(preset("nope").is_err());
    }

    #[test]
    fn fingerprint_distinguishes_candidates() {
        let a = Candidate(vec![1, 2, 3]);
        let b = Candidate(vec![1, 2, 4]);
        assert_ne!(a.fingerprint(), b.fingerprint());
        assert_eq!(a.fingerprint(), Candidate(vec![1, 2, 3]).fingerprint());
    }
}
