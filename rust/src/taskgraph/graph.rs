//! The dependency graph `G = (V, D)` of §6.1.
//!
//! Nodes are tasks (compute / storage / comm / sync); directed edges are
//! data dependencies. Deleted tasks leave tombstones so `TaskId`s stay
//! stable across graph-transformation primitives (required for undo/redo).

use std::collections::VecDeque;

use super::task::{Task, TaskId, TaskKind};

/// Mutable task dependency graph.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TaskGraph {
    tasks: Vec<Option<Task>>,
    out_edges: Vec<Vec<TaskId>>,
    in_edges: Vec<Vec<TaskId>>,
}

impl TaskGraph {
    pub fn new() -> Self {
        Self::default()
    }

    // ------------------------------------------------------------------
    // Construction
    // ------------------------------------------------------------------

    /// Add a task; returns its id.
    pub fn add(&mut self, name: impl Into<String>, kind: TaskKind) -> TaskId {
        let id = TaskId(self.tasks.len() as u32);
        self.tasks.push(Some(Task::new(id, name, kind)));
        self.out_edges.push(Vec::new());
        self.in_edges.push(Vec::new());
        id
    }

    /// Add a data dependency `src -> dst`. Duplicate edges are ignored.
    pub fn connect(&mut self, src: TaskId, dst: TaskId) {
        assert!(self.contains(src), "connect: missing src {src}");
        assert!(self.contains(dst), "connect: missing dst {dst}");
        assert_ne!(src, dst, "self-dependency {src}");
        if !self.out_edges[src.index()].contains(&dst) {
            self.out_edges[src.index()].push(dst);
            self.in_edges[dst.index()].push(src);
        }
    }

    /// Remove the dependency `src -> dst` if present.
    pub fn disconnect(&mut self, src: TaskId, dst: TaskId) {
        self.out_edges[src.index()].retain(|t| *t != dst);
        self.in_edges[dst.index()].retain(|t| *t != src);
    }

    /// Delete a task and all incident edges (tombstoned).
    pub fn remove(&mut self, id: TaskId) -> Option<Task> {
        let task = self.tasks.get_mut(id.index())?.take()?;
        let preds = std::mem::take(&mut self.in_edges[id.index()]);
        for p in preds {
            self.out_edges[p.index()].retain(|t| *t != id);
        }
        let succs = std::mem::take(&mut self.out_edges[id.index()]);
        for s in succs {
            self.in_edges[s.index()].retain(|t| *t != id);
        }
        Some(task)
    }

    // ------------------------------------------------------------------
    // Access
    // ------------------------------------------------------------------

    pub fn contains(&self, id: TaskId) -> bool {
        self.tasks.get(id.index()).is_some_and(Option::is_some)
    }

    pub fn task(&self, id: TaskId) -> &Task {
        self.tasks[id.index()].as_ref().expect("task deleted")
    }

    pub fn get(&self, id: TaskId) -> Option<&Task> {
        self.tasks.get(id.index())?.as_ref()
    }

    pub fn task_mut(&mut self, id: TaskId) -> &mut Task {
        self.tasks[id.index()].as_mut().expect("task deleted")
    }

    /// Live tasks.
    pub fn iter(&self) -> impl Iterator<Item = &Task> {
        self.tasks.iter().filter_map(Option::as_ref)
    }

    pub fn ids(&self) -> impl Iterator<Item = TaskId> + '_ {
        self.iter().map(|t| t.id)
    }

    /// Number of live tasks.
    pub fn len(&self) -> usize {
        self.tasks.iter().filter(|t| t.is_some()).count()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Upper bound over ever-allocated ids (tombstones included) — the size
    /// to use for id-indexed side tables.
    pub fn capacity(&self) -> usize {
        self.tasks.len()
    }

    pub fn successors(&self, id: TaskId) -> &[TaskId] {
        &self.out_edges[id.index()]
    }

    pub fn predecessors(&self, id: TaskId) -> &[TaskId] {
        &self.in_edges[id.index()]
    }

    /// Per-task count of *enabled* predecessors, indexed by
    /// [`TaskId::index`] (tombstones and disabled tasks get 0). The
    /// simulator's dependency counters are seeded from this once per run
    /// instead of re-filtering predecessor lists per (task, iteration).
    pub fn enabled_in_degrees(&self) -> Vec<u32> {
        let mut deg = Vec::new();
        self.enabled_in_degrees_into(&mut deg);
        deg
    }

    /// [`Self::enabled_in_degrees`] into a caller-owned buffer, for
    /// simulation sessions that reuse their arenas across runs.
    pub fn enabled_in_degrees_into(&self, deg: &mut Vec<u32>) {
        deg.clear();
        deg.resize(self.tasks.len(), 0);
        for t in self.iter().filter(|t| t.enabled) {
            deg[t.id.index()] = self.in_edges[t.id.index()]
                .iter()
                .filter(|p| self.task(**p).enabled)
                .count() as u32;
        }
    }

    /// Tasks with no predecessors (simulation entry points).
    pub fn sources(&self) -> Vec<TaskId> {
        self.iter()
            .filter(|t| self.in_edges[t.id.index()].is_empty())
            .map(|t| t.id)
            .collect()
    }

    /// Tasks with no successors.
    pub fn sinks(&self) -> Vec<TaskId> {
        self.iter()
            .filter(|t| self.out_edges[t.id.index()].is_empty())
            .map(|t| t.id)
            .collect()
    }

    // ------------------------------------------------------------------
    // Analysis
    // ------------------------------------------------------------------

    /// Kahn topological order; `None` if the graph has a cycle.
    pub fn toposort(&self) -> Option<Vec<TaskId>> {
        let mut indeg = vec![0usize; self.tasks.len()];
        for t in self.iter() {
            indeg[t.id.index()] = self.in_edges[t.id.index()].len();
        }
        let mut queue: VecDeque<TaskId> = self
            .iter()
            .filter(|t| indeg[t.id.index()] == 0)
            .map(|t| t.id)
            .collect();
        let mut order = Vec::with_capacity(self.len());
        while let Some(id) = queue.pop_front() {
            order.push(id);
            for &s in &self.out_edges[id.index()] {
                indeg[s.index()] -= 1;
                if indeg[s.index()] == 0 {
                    queue.push_back(s);
                }
            }
        }
        if order.len() == self.len() {
            Some(order)
        } else {
            None
        }
    }

    pub fn has_cycle(&self) -> bool {
        self.toposort().is_none()
    }

    /// `a <_d b`: b depends (transitively) on a.
    pub fn depends_on(&self, b: TaskId, a: TaskId) -> bool {
        if a == b {
            return false;
        }
        let mut seen = vec![false; self.tasks.len()];
        let mut stack = vec![a];
        while let Some(n) = stack.pop() {
            for &s in &self.out_edges[n.index()] {
                if s == b {
                    return true;
                }
                if !seen[s.index()] {
                    seen[s.index()] = true;
                    stack.push(s);
                }
            }
        }
        false
    }

    /// Structural sanity: edge symmetry and no edges touching tombstones.
    /// Returns structured diagnostics (`MLDSE-E060..E062`, empty = valid)
    /// so callers and tests can match on stable codes instead of message
    /// substrings.
    pub fn validate(&self) -> Vec<crate::analyze::Diagnostic> {
        use crate::analyze::diag::{
            Diagnostic, E060_TOMBSTONE_EDGES, E061_DANGLING_EDGE, E062_ASYMMETRIC_EDGE,
        };
        let mut problems = Vec::new();
        for (i, slot) in self.tasks.iter().enumerate() {
            let id = TaskId(i as u32);
            if slot.is_none() {
                if !self.out_edges[i].is_empty() || !self.in_edges[i].is_empty() {
                    problems.push(Diagnostic::error(
                        E060_TOMBSTONE_EDGES,
                        id.to_string(),
                        format!("tombstone {id} has incident edges"),
                    ));
                }
                continue;
            }
            for &s in &self.out_edges[i] {
                if !self.contains(s) {
                    problems.push(Diagnostic::error(
                        E061_DANGLING_EDGE,
                        id.to_string(),
                        format!("edge {id}->{s} targets a deleted task"),
                    ));
                } else if !self.in_edges[s.index()].contains(&id) {
                    problems.push(Diagnostic::error(
                        E062_ASYMMETRIC_EDGE,
                        id.to_string(),
                        format!("edge {id}->{s} missing reverse entry"),
                    ));
                }
            }
            for &p in &self.in_edges[i] {
                if !self.contains(p) {
                    problems.push(Diagnostic::error(
                        E061_DANGLING_EDGE,
                        id.to_string(),
                        format!("edge {p}->{id} from a deleted task"),
                    ));
                } else if !self.out_edges[p.index()].contains(&id) {
                    problems.push(Diagnostic::error(
                        E062_ASYMMETRIC_EDGE,
                        id.to_string(),
                        format!("edge {p}->{id} missing forward entry"),
                    ));
                }
            }
        }
        problems
    }

    /// Count of live edges.
    pub fn num_edges(&self) -> usize {
        self.iter()
            .map(|t| self.out_edges[t.id.index()].len())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::taskgraph::task::{ComputeCost, OpClass};

    fn compute() -> TaskKind {
        TaskKind::Compute(ComputeCost::zero(OpClass::Custom))
    }

    fn diamond() -> (TaskGraph, [TaskId; 4]) {
        let mut g = TaskGraph::new();
        let a = g.add("a", compute());
        let b = g.add("b", compute());
        let c = g.add("c", compute());
        let d = g.add("d", compute());
        g.connect(a, b);
        g.connect(a, c);
        g.connect(b, d);
        g.connect(c, d);
        (g, [a, b, c, d])
    }

    #[test]
    fn add_connect_query() {
        let (g, [a, b, c, d]) = diamond();
        assert_eq!(g.len(), 4);
        assert_eq!(g.num_edges(), 4);
        assert_eq!(g.successors(a), &[b, c]);
        assert_eq!(g.predecessors(d), &[b, c]);
        assert_eq!(g.sources(), vec![a]);
        assert_eq!(g.sinks(), vec![d]);
        assert!(g.validate().is_empty());
    }

    #[test]
    fn duplicate_edges_ignored() {
        let mut g = TaskGraph::new();
        let a = g.add("a", compute());
        let b = g.add("b", compute());
        g.connect(a, b);
        g.connect(a, b);
        assert_eq!(g.num_edges(), 1);
    }

    #[test]
    fn remove_cleans_edges() {
        let (mut g, [a, b, c, d]) = diamond();
        g.remove(b);
        assert_eq!(g.len(), 3);
        assert!(!g.contains(b));
        assert_eq!(g.successors(a), &[c]);
        assert_eq!(g.predecessors(d), &[c]);
        assert!(g.validate().is_empty());
        // ids remain stable
        assert_eq!(g.task(c).id, c);
    }

    #[test]
    fn toposort_respects_deps() {
        let (g, [a, b, c, d]) = diamond();
        let order = g.toposort().unwrap();
        let pos = |t: TaskId| order.iter().position(|x| *x == t).unwrap();
        assert!(pos(a) < pos(b) && pos(a) < pos(c));
        assert!(pos(b) < pos(d) && pos(c) < pos(d));
    }

    #[test]
    fn enabled_in_degrees_skip_disabled_and_tombstones() {
        let (mut g, [a, b, c, d]) = diamond();
        g.task_mut(b).enabled = false;
        let deg = g.enabled_in_degrees();
        assert_eq!(deg[a.index()], 0);
        assert_eq!(deg[b.index()], 0); // disabled task itself zeroed
        assert_eq!(deg[c.index()], 1);
        assert_eq!(deg[d.index()], 1); // only c counts, b is disabled
        g.remove(c);
        let deg = g.enabled_in_degrees();
        assert_eq!(deg[c.index()], 0); // tombstone
        assert_eq!(deg[d.index()], 0);
    }

    #[test]
    fn cycle_detection() {
        let mut g = TaskGraph::new();
        let a = g.add("a", compute());
        let b = g.add("b", compute());
        g.connect(a, b);
        assert!(!g.has_cycle());
        g.connect(b, a);
        assert!(g.has_cycle());
    }

    #[test]
    fn depends_on_transitive() {
        let (g, [a, b, _c, d]) = diamond();
        assert!(g.depends_on(d, a));
        assert!(g.depends_on(b, a));
        assert!(!g.depends_on(a, d));
        assert!(!g.depends_on(a, a));
    }

    #[test]
    fn validate_reports_structured_codes() {
        use crate::analyze::diag;
        use crate::analyze::Severity;
        // Tombstone a slot without cleaning its edges: E060 on the
        // tombstone plus E061 on every live edge touching it.
        let (mut g, [_a, b, _c, _d]) = diamond();
        g.tasks[b.index()] = None;
        let problems = g.validate();
        assert!(problems.iter().any(|d| d.code == diag::E060_TOMBSTONE_EDGES), "{problems:?}");
        assert!(problems.iter().any(|d| d.code == diag::E061_DANGLING_EDGE), "{problems:?}");
        assert!(problems.iter().all(|d| d.severity == Severity::Error));
        // Drop a reverse entry only: E062.
        let (mut g2, [a2, b2, _c2, _d2]) = diamond();
        g2.in_edges[b2.index()].retain(|t| *t != a2);
        let problems = g2.validate();
        assert!(problems.iter().any(|d| d.code == diag::E062_ASYMMETRIC_EDGE), "{problems:?}");
    }

    #[test]
    #[should_panic(expected = "self-dependency")]
    fn self_edge_panics() {
        let mut g = TaskGraph::new();
        let a = g.add("a", compute());
        g.connect(a, a);
    }

    #[test]
    fn prop_random_dag_toposort_valid() {
        use crate::util::propcheck::{check, Gen};
        check("random DAG toposorts consistently", 64, |g: &mut Gen| {
            let n = g.usize(1..=30);
            let mut tg = TaskGraph::new();
            let ids: Vec<TaskId> = (0..n).map(|i| tg.add(format!("t{i}"), compute())).collect();
            // forward edges only => acyclic by construction
            for i in 0..n {
                for j in i + 1..n {
                    if g.bool() && g.bool() {
                        tg.connect(ids[i], ids[j]);
                    }
                }
            }
            let order = tg.toposort().ok_or("cycle in DAG?!")?;
            let pos: std::collections::HashMap<TaskId, usize> =
                order.iter().enumerate().map(|(i, t)| (*t, i)).collect();
            for t in tg.ids() {
                for &s in tg.successors(t) {
                    if pos[&t] >= pos[&s] {
                        return Err(format!("order violates {t}->{s}"));
                    }
                }
            }
            if !tg.validate().is_empty() {
                return Err("validate failed".into());
            }
            Ok(())
        });
    }
}
