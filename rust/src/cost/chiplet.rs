//! Chiplet cost model (after Chiplet Actuary [Feng & Ma, DAC'22]; paper
//! Fig. 10(c,d)).
//!
//! Die cost uses the negative-binomial yield model; packaging cost covers
//! organic-substrate MCM and silicon-interposer 2.5D integration. The model
//! reproduces the qualitative Fig.-10 trade-off: more chiplets per package
//! replace slow board links with fast NoP links but raise packaging cost —
//! with an optimum at a small chiplet count.

/// Packaging technology.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Packaging {
    /// Multi-chip module on an organic substrate.
    Mcm,
    /// 2.5D silicon interposer (higher cost, better links).
    Interposer2_5D,
}

impl Packaging {
    pub fn name(&self) -> &'static str {
        match self {
            Packaging::Mcm => "MCM",
            Packaging::Interposer2_5D => "2.5D",
        }
    }
}

/// Cost-model parameters (USD; 7nm-class logic wafers).
#[derive(Debug, Clone)]
pub struct CostModel {
    /// Wafer cost for the compute die node.
    pub wafer_cost: f64,
    /// Wafer diameter in mm.
    pub wafer_diameter: f64,
    /// Defect density per mm².
    pub defect_density: f64,
    /// Negative-binomial clustering parameter.
    pub alpha: f64,
    /// Organic substrate cost coefficient (applied to area^exponent).
    pub substrate_cost_per_mm2: f64,
    /// Silicon interposer cost coefficient (coarse node wafer).
    pub interposer_cost_per_mm2: f64,
    /// Superlinear exponent on carrier (substrate/interposer) area —
    /// large carriers yield worse and route harder (Chiplet Actuary).
    pub carrier_exponent: f64,
    /// Per-chiplet bonding cost, MCM.
    pub bond_cost_mcm: f64,
    /// Per-chiplet bonding cost, 2.5D (micro-bumps).
    pub bond_cost_2_5d: f64,
    /// Bonding yield per chiplet placement.
    pub bond_yield: f64,
    /// Package area overhead factor over summed die area.
    pub package_area_factor: f64,
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel {
            wafer_cost: 9350.0,
            wafer_diameter: 300.0,
            defect_density: 0.0025, // per mm²
            alpha: 4.0,
            substrate_cost_per_mm2: 0.03,
            interposer_cost_per_mm2: 0.09,
            carrier_exponent: 1.3,
            bond_cost_mcm: 2.0,
            bond_cost_2_5d: 6.0,
            bond_yield: 0.99,
            package_area_factor: 1.8,
        }
    }
}

impl CostModel {
    /// Dies per wafer (Seeds' formula).
    pub fn dies_per_wafer(&self, die_area: f64) -> f64 {
        let d = self.wafer_diameter;
        let r = d / 2.0;
        (std::f64::consts::PI * r * r / die_area)
            - (std::f64::consts::PI * d / (2.0 * die_area.sqrt()))
    }

    /// Negative-binomial die yield.
    pub fn die_yield(&self, die_area: f64) -> f64 {
        (1.0 + self.defect_density * die_area / self.alpha).powf(-self.alpha)
    }

    /// Cost of one *good* die of `die_area` mm².
    pub fn die_cost(&self, die_area: f64) -> f64 {
        self.wafer_cost / self.dies_per_wafer(die_area) / self.die_yield(die_area)
    }

    /// Cost of one package holding `n` chiplets of `chiplet_area` each.
    pub fn package_cost(&self, n: usize, chiplet_area: f64, pkg: Packaging) -> f64 {
        assert!(n >= 1);
        let dies = n as f64 * self.die_cost(chiplet_area);
        let pkg_area = n as f64 * chiplet_area * self.package_area_factor;
        let carrier_area = pkg_area.powf(self.carrier_exponent);
        let (carrier, bond) = match pkg {
            Packaging::Mcm => (
                carrier_area * self.substrate_cost_per_mm2,
                n as f64 * self.bond_cost_mcm,
            ),
            Packaging::Interposer2_5D => (
                carrier_area * self.interposer_cost_per_mm2,
                n as f64 * self.bond_cost_2_5d,
            ),
        };
        // assembly yield: every placement must succeed
        let assembly_yield = self.bond_yield.powi(n as i32);
        (dies + carrier + bond) / assembly_yield
    }

    /// Cost of a system of `total_chiplets` spread over packages of
    /// `chiplets_per_package` (plus one board cost per package).
    pub fn system_cost(
        &self,
        total_chiplets: usize,
        chiplets_per_package: usize,
        chiplet_area: f64,
        pkg: Packaging,
    ) -> f64 {
        assert!(total_chiplets % chiplets_per_package == 0);
        let packages = total_chiplets / chiplets_per_package;
        let board_cost_per_pkg = 12.0; // socket + routing share
        packages as f64 * (self.package_cost(chiplets_per_package, chiplet_area, pkg)
            + board_cost_per_pkg)
    }

    /// Monolithic-die cost for the same total area (the classic chiplet
    /// motivation: one big die yields terribly).
    pub fn monolithic_cost(&self, total_area: f64) -> f64 {
        self.die_cost(total_area) + 20.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn yield_decreases_with_area() {
        let m = CostModel::default();
        assert!(m.die_yield(100.0) > m.die_yield(800.0));
        assert!(m.die_yield(100.0) <= 1.0);
    }

    #[test]
    fn die_cost_superlinear_in_area() {
        let m = CostModel::default();
        // doubling area more than doubles cost (fewer dies + worse yield)
        assert!(m.die_cost(800.0) > 2.0 * m.die_cost(400.0));
    }

    #[test]
    fn chiplets_cheaper_than_monolithic_at_scale() {
        let m = CostModel::default();
        // 4 x 200mm² chiplets vs one 800mm² die
        let chiplet = m.package_cost(4, 200.0, Packaging::Mcm);
        let mono = m.monolithic_cost(800.0);
        assert!(chiplet < mono, "chiplet {chiplet} vs mono {mono}");
    }

    #[test]
    fn interposer_costs_more_than_mcm() {
        let m = CostModel::default();
        assert!(
            m.package_cost(4, 200.0, Packaging::Interposer2_5D)
                > m.package_cost(4, 200.0, Packaging::Mcm)
        );
    }

    #[test]
    fn system_cost_grows_with_chiplets_per_package() {
        // For a fixed 24-chiplet system, packaging more chiplets together
        // raises total cost (bigger carriers, worse assembly yield) --
        // the cost half of the Fig. 10(d) trade-off.
        let m = CostModel::default();
        let c1 = m.system_cost(24, 1, 150.0, Packaging::Mcm);
        let c2 = m.system_cost(24, 2, 150.0, Packaging::Mcm);
        let c6 = m.system_cost(24, 6, 150.0, Packaging::Mcm);
        assert!(c2 > c1 * 0.8, "sanity");
        assert!(c6 > c2, "more chiplets per package must cost more: {c2} vs {c6}");
    }

    #[test]
    fn dies_per_wafer_sane() {
        let m = CostModel::default();
        let n = m.dies_per_wafer(100.0);
        assert!((500.0..700.0).contains(&n), "dies/wafer {n}");
    }
}
