//! Bench: regenerate the paper artifact via the `fig9-cross` experiment
//! (see DESIGN.md §3 for the experiment index). Run with
//! `cargo bench --bench fig9_cross_arch` (add MLDSE_BENCH_QUICK=1 for small sizes).

#[path = "common/mod.rs"]
mod common;

fn main() {
    common::run_experiment("fig9-cross");
}
