//! Minimal HTTP/1.1 plumbing for the serve daemon.
//!
//! The crate is zero-dependency, so this is a hand-rolled subset of the
//! protocol — exactly what the job API needs and nothing more: one
//! request per connection (`Connection: close`), `Content-Length` bodies
//! on the way in, and either fixed-length JSON or chunked NDJSON on the
//! way out. Parsing is strict about the request line and tolerant about
//! headers it does not understand.

use std::io::{BufRead, Read, Write};

use crate::util::error::Result;

/// Largest request body the daemon will read (space documents are small;
/// anything bigger is a client error, not a reason to balloon memory).
pub const MAX_BODY_BYTES: usize = 16 * 1024 * 1024;

/// One parsed request: method, raw path (query string included) and the
/// decoded UTF-8 body (empty when the request had none).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub body: String,
}

/// Read one request from `r`. Headers other than `Content-Length` are
/// skipped; the body is read to exactly the declared length.
pub fn parse_request<R: BufRead>(r: &mut R) -> Result<Request> {
    let mut start = String::new();
    let n = r.read_line(&mut start)?;
    crate::ensure!(n > 0, "http: connection closed before a request line");
    let mut parts = start.split_whitespace();
    let method = parts.next().unwrap_or_default().to_string();
    let path = parts.next().unwrap_or_default().to_string();
    crate::ensure!(
        !method.is_empty() && path.starts_with('/'),
        "http: malformed request line '{}'",
        start.trim_end()
    );
    let mut content_len = 0usize;
    loop {
        let mut line = String::new();
        if r.read_line(&mut line)? == 0 {
            break;
        }
        let line = line.trim_end();
        if line.is_empty() {
            break;
        }
        if let Some((key, value)) = line.split_once(':') {
            if key.trim().eq_ignore_ascii_case("content-length") {
                let value = value.trim();
                content_len = value.parse().map_err(|_| {
                    crate::format_err!("http: invalid Content-Length '{value}'")
                })?;
            }
        }
    }
    crate::ensure!(
        content_len <= MAX_BODY_BYTES,
        "http: request body too large ({content_len} bytes, limit {MAX_BODY_BYTES})"
    );
    let mut body = vec![0u8; content_len];
    r.read_exact(&mut body)?;
    let body = String::from_utf8(body)
        .map_err(|_| crate::format_err!("http: request body is not valid UTF-8"))?;
    Ok(Request { method, path, body })
}

/// Canonical reason phrase for the status codes the daemon emits.
pub fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        201 => "Created",
        202 => "Accepted",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        409 => "Conflict",
        422 => "Unprocessable Entity",
        500 => "Internal Server Error",
        _ => "Unknown",
    }
}

/// Write a complete fixed-length response and flush it.
pub fn write_response(
    w: &mut impl Write,
    status: u16,
    content_type: &str,
    body: &str,
) -> std::io::Result<()> {
    write!(
        w,
        "HTTP/1.1 {status} {}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        reason(status),
        body.len()
    )?;
    w.write_all(body.as_bytes())?;
    w.flush()
}

/// Write a JSON response (pretty-printed, newline-terminated).
pub fn write_json(
    w: &mut impl Write,
    status: u16,
    doc: &crate::util::json::Json,
) -> std::io::Result<()> {
    let body = format!("{}\n", doc.to_pretty());
    write_response(w, status, "application/json", &body)
}

/// Start a chunked 200 response; follow with [`write_chunk`] and close
/// with [`finish_chunked`].
pub fn start_chunked(w: &mut impl Write, content_type: &str) -> std::io::Result<()> {
    write!(
        w,
        "HTTP/1.1 200 OK\r\nContent-Type: {content_type}\r\nTransfer-Encoding: chunked\r\nConnection: close\r\n\r\n"
    )?;
    w.flush()
}

/// Write one chunk. Empty data is skipped — a zero-length chunk would
/// terminate the stream.
pub fn write_chunk(w: &mut impl Write, data: &str) -> std::io::Result<()> {
    if data.is_empty() {
        return Ok(());
    }
    write!(w, "{:x}\r\n", data.len())?;
    w.write_all(data.as_bytes())?;
    w.write_all(b"\r\n")?;
    w.flush()
}

/// Terminate a chunked response.
pub fn finish_chunked(w: &mut impl Write) -> std::io::Result<()> {
    w.write_all(b"0\r\n\r\n")?;
    w.flush()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn parses_request_with_body() {
        let raw = "POST /jobs HTTP/1.1\r\nHost: x\r\nContent-Length: 4\r\n\r\nabcd";
        let req = parse_request(&mut Cursor::new(raw.as_bytes())).unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/jobs");
        assert_eq!(req.body, "abcd");
    }

    #[test]
    fn parses_bodyless_request_and_case_insensitive_header() {
        let raw = "GET /jobs/1 HTTP/1.1\r\ncontent-LENGTH: 0\r\n\r\n";
        let req = parse_request(&mut Cursor::new(raw.as_bytes())).unwrap();
        assert_eq!(req.method, "GET");
        assert_eq!(req.path, "/jobs/1");
        assert!(req.body.is_empty());
    }

    #[test]
    fn rejects_garbage_request_line() {
        let err = parse_request(&mut Cursor::new(b"nonsense\r\n\r\n".as_slice()))
            .unwrap_err()
            .to_string();
        assert!(err.contains("malformed request line"), "{err}");
    }

    #[test]
    fn rejects_invalid_content_length() {
        let raw = "POST /jobs HTTP/1.1\r\nContent-Length: lots\r\n\r\n";
        let err = parse_request(&mut Cursor::new(raw.as_bytes()))
            .unwrap_err()
            .to_string();
        assert!(err.contains("invalid Content-Length 'lots'"), "{err}");
    }

    #[test]
    fn chunked_framing_round_trips() {
        let mut out: Vec<u8> = Vec::new();
        start_chunked(&mut out, "application/x-ndjson").unwrap();
        write_chunk(&mut out, "hello\n").unwrap();
        write_chunk(&mut out, "").unwrap(); // skipped, not a terminator
        write_chunk(&mut out, "world\n").unwrap();
        finish_chunked(&mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"), "{text}");
        assert!(text.contains("Transfer-Encoding: chunked"), "{text}");
        assert!(text.contains("6\r\nhello\n\r\n"), "{text}");
        assert!(text.ends_with("0\r\n\r\n"), "{text}");
    }

    #[test]
    fn fixed_response_has_content_length() {
        let mut out: Vec<u8> = Vec::new();
        write_response(&mut out, 404, "application/json", "{}\n").unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 404 Not Found\r\n"), "{text}");
        assert!(text.contains("Content-Length: 3\r\n"), "{text}");
        assert!(text.ends_with("{}\n"), "{text}");
    }
}
