//! Static lints over bench scenario documents (`mldse bench run`).
//!
//! [`crate::bench::Scenario::from_json`] already rejects unknown
//! families, unknown explorers, and malformed fields — those surface
//! here as `MLDSE-E050`. On top of that: a custom scenario's space file
//! is read and run through the full space check (its findings keep their
//! own codes, with the source path prefixed by the file), and grid
//! explorations whose budget falls short of the space size are flagged —
//! a grid enumerates candidates in order, so a short budget silently
//! truncates the sweep to a fixed prefix of the space, which is almost
//! never what "exhaustive grid" was chosen for. (Budget *beyond* the
//! size is fine: the grid simply stops when the space is exhausted, and
//! shipped scenarios use that to guarantee full coverage.)

use crate::bench::Scenario;
use crate::util::json::Json;

use super::diag::{self, Diagnostic};
use super::space::check_space_doc;

/// Run every scenario check on an already-parsed JSON document. `origin`
/// is the scenario's file path — relative `"space"` references resolve
/// against its directory. Returns a sorted diagnostic list.
pub fn check_scenario_doc(doc: &Json, origin: &str) -> Vec<Diagnostic> {
    let scenario = match Scenario::from_json(doc, origin) {
        Ok(s) => s,
        Err(e) => {
            return vec![Diagnostic::error(
                diag::E050_SCENARIO_INVALID,
                "",
                format!("{e:#}"),
            )];
        }
    };
    check_scenario(&scenario)
}

/// Check an already-parsed [`Scenario`] (shared by the CLI and the
/// `bench run` pre-flight).
pub fn check_scenario(s: &Scenario) -> Vec<Diagnostic> {
    let mut diags = Vec::new();

    if let Some(path) = &s.space_file {
        let shown = path.display();
        match std::fs::read_to_string(path) {
            Err(e) => diags.push(Diagnostic::error(
                diag::E052_SCENARIO_SPACE_FILE,
                "space",
                format!("reading space file '{shown}': {e}"),
            )),
            Ok(text) => match Json::parse(&text) {
                Err(e) => diags.push(Diagnostic::error(
                    diag::E052_SCENARIO_SPACE_FILE,
                    "space",
                    format!("parsing space file '{shown}': {e}"),
                )),
                Ok(doc) => {
                    for mut d in check_space_doc(&doc) {
                        d.at = if d.at.is_empty() {
                            shown.to_string()
                        } else {
                            format!("{shown}: {}", d.at)
                        };
                        diags.push(d);
                    }
                }
            },
        }
    }

    if s.explorer == "grid" {
        // A grid enumerates candidates in order and stops at the budget;
        // a budget below the space size truncates the sweep to a fixed
        // prefix. Check full and quick modes (their presets — and
        // therefore sizes — may differ), deduplicating when they
        // coincide.
        let mut checked: Vec<(usize, u64)> = Vec::new();
        for (quick, label) in [(false, "budget"), (true, "quick_budget")] {
            let Ok((space, _)) = s.resolve(quick) else {
                continue; // resolution failures already reported above
            };
            let size = space.size();
            let budget = s.effective_budget(quick);
            if (budget as u64) < size && !checked.contains(&(budget, size)) {
                checked.push((budget, size));
                diags.push(Diagnostic::warning(
                    diag::W051_PARTIAL_GRID,
                    label,
                    format!(
                        "grid {label} {budget} covers only a fixed prefix of the \
                         {size}-candidate space; raise it to {size} for full \
                         coverage or switch to a sampling explorer"
                    ),
                ));
            }
        }
    }

    if s.overrides.surrogate == Some(true) {
        // Warmup forwards every proposal to the exact simulator; once it
        // meets the budget the gate never makes a single decision, so the
        // scenario pays the surrogate's training cost for zero skips.
        let warmup = s
            .overrides
            .surrogate_warmup
            .unwrap_or_else(|| crate::dse::explore::SurrogateCfg::with_seed(0).warmup);
        let mut checked: Vec<usize> = Vec::new();
        for (quick, label) in [(false, "budget"), (true, "quick_budget")] {
            let budget = s.effective_budget(quick);
            if warmup >= budget && !checked.contains(&budget) {
                checked.push(budget);
                diags.push(Diagnostic::warning(
                    diag::W053_SURROGATE_WARMUP,
                    label,
                    format!(
                        "surrogate warmup {warmup} meets or exceeds the {label} of \
                         {budget}, so every candidate is simulated exactly and the \
                         gate never skips; lower the warmup or disable the surrogate"
                    ),
                ));
            }
        }
    }

    diag::sort(&mut diags);
    diags
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analyze::diag::Severity;

    fn check(text: &str) -> Vec<Diagnostic> {
        check_scenario_doc(&Json::parse(text).unwrap(), "test.json")
    }

    #[test]
    fn invalid_scenario_is_e050() {
        let d = check(r#"{"name": "s", "family": "warp-drive", "budget": 8}"#);
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].code, diag::E050_SCENARIO_INVALID);
        assert_eq!(d[0].severity, Severity::Error);
        let d = check(r#"{"name": "s", "family": "mapping", "budget": 8, "explorer": "psychic"}"#);
        assert_eq!(d[0].code, diag::E050_SCENARIO_INVALID, "{d:?}");
    }

    #[test]
    fn missing_space_file_is_e052() {
        let d = check(
            r#"{"name": "s", "family": "custom", "budget": 8,
                "space": "does/not/exist.json"}"#,
        );
        assert!(d.iter().any(|x| x.code == diag::E052_SCENARIO_SPACE_FILE), "{d:?}");
    }

    #[test]
    fn grid_budget_short_of_space_size_is_w051() {
        // The mapping preset space has 4^8 = 65536 candidates; a grid
        // budget of 128 silently sweeps a fixed prefix.
        let d = check(
            r#"{"name": "s", "family": "mapping", "explorer": "grid",
                "budget": 128, "quick_budget": 24}"#,
        );
        assert!(d.iter().any(|x| x.code == diag::W051_PARTIAL_GRID), "{d:?}");
        // Budget at (or beyond) the size is full coverage — clean. The
        // packaging space has 10 full / 4 quick candidates, mirroring
        // the shipped packaging-grid scenario's over-provisioned budget.
        let d = check(
            r#"{"name": "s", "family": "packaging-decode", "explorer": "grid",
                "budget": 64, "quick_budget": 12}"#,
        );
        assert!(d.is_empty(), "{d:?}");
        // Non-grid explorers never warn: over- or under-sampling a space
        // with anneal/random is a deliberate methodology choice.
        let d = check(
            r#"{"name": "s", "family": "mapping", "explorer": "anneal",
                "budget": 128, "quick_budget": 24}"#,
        );
        assert!(d.is_empty(), "{d:?}");
    }

    #[test]
    fn surrogate_warmup_at_or_over_budget_is_w053() {
        // default warmup (12) >= quick_budget 8: only the quick mode warns
        let d = check(
            r#"{"name": "s", "family": "mapping", "explorer": "anneal",
                "budget": 64, "quick_budget": 8,
                "overrides": {"surrogate": true}}"#,
        );
        let w: Vec<_> = d.iter().filter(|x| x.code == diag::W053_SURROGATE_WARMUP).collect();
        assert_eq!(w.len(), 1, "{d:?}");
        assert_eq!(w[0].at, "quick_budget");
        assert!(w[0].message.contains("warmup 12"), "{}", w[0].message);

        // explicit warmup over both budgets warns once per distinct budget
        let d = check(
            r#"{"name": "s", "family": "mapping", "explorer": "anneal",
                "budget": 16, "quick_budget": 8,
                "overrides": {"surrogate": true, "surrogate_warmup": 20}}"#,
        );
        assert_eq!(
            d.iter().filter(|x| x.code == diag::W053_SURROGATE_WARMUP).count(),
            2,
            "{d:?}"
        );

        // warmup safely under the budget: clean
        let d = check(
            r#"{"name": "s", "family": "mapping", "explorer": "anneal",
                "budget": 64, "quick_budget": 24,
                "overrides": {"surrogate": true, "surrogate_warmup": 6}}"#,
        );
        assert!(d.is_empty(), "{d:?}");

        // surrogate off: no warning regardless of budget
        let d = check(
            r#"{"name": "s", "family": "mapping", "explorer": "anneal",
                "budget": 4}"#,
        );
        assert!(d.is_empty(), "{d:?}");
    }
}
