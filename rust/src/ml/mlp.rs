//! A small MLP regressor: tanh hidden layers, linear output, MSE loss,
//! trained by minibatch SGD or Adam with backprop. Weight init and
//! minibatch shuffles draw from a caller-supplied [`Pcg`], so training is
//! a pure function of `(architecture, data, hyperparameters, seed)` —
//! the property the surrogate gate's checkpoint/resume bit-identity
//! rests on.

use crate::util::rng::Pcg;

use super::linalg::Matrix;

/// Training hyperparameters shared by [`Mlp::fit_sgd`] and
/// [`Mlp::fit_adam`].
#[derive(Debug, Clone)]
pub struct FitOpts {
    pub epochs: usize,
    /// Minibatch size (clamped to the dataset size; 0 behaves as 1).
    pub batch: usize,
    pub lr: f64,
}

impl Default for FitOpts {
    fn default() -> Self {
        FitOpts {
            epochs: 40,
            batch: 8,
            lr: 0.01,
        }
    }
}

/// Multi-layer perceptron: `sizes = [in, hidden..., out]`, tanh hidden
/// activations, linear output head.
#[derive(Debug, Clone)]
pub struct Mlp {
    sizes: Vec<usize>,
    /// Per layer: `sizes[l+1] × sizes[l]` weight matrix.
    weights: Vec<Matrix>,
    /// Per layer: `sizes[l+1]` bias vector.
    biases: Vec<Vec<f64>>,
}

impl Mlp {
    /// A network with Xavier/Glorot-uniform init drawn from `rng`. At
    /// least an input and an output layer are required.
    pub fn new(sizes: &[usize], rng: &mut Pcg) -> Mlp {
        assert!(sizes.len() >= 2, "mlp needs at least [in, out] sizes");
        assert!(sizes.iter().all(|&s| s > 0), "mlp layer sizes must be > 0");
        let mut weights = Vec::with_capacity(sizes.len() - 1);
        let mut biases = Vec::with_capacity(sizes.len() - 1);
        for l in 0..sizes.len() - 1 {
            let (fan_in, fan_out) = (sizes[l], sizes[l + 1]);
            let bound = (6.0 / (fan_in + fan_out) as f64).sqrt();
            let mut w = Matrix::zeros(fan_out, fan_in);
            for v in &mut w.data {
                *v = rng.range_f64(-bound, bound);
            }
            weights.push(w);
            biases.push(vec![0.0; fan_out]);
        }
        Mlp {
            sizes: sizes.to_vec(),
            weights,
            biases,
        }
    }

    pub fn in_dim(&self) -> usize {
        self.sizes[0]
    }

    pub fn out_dim(&self) -> usize {
        *self.sizes.last().unwrap()
    }

    /// Forward pass; `x.len()` must equal [`Mlp::in_dim`].
    pub fn forward(&self, x: &[f64]) -> Vec<f64> {
        self.activations(x).pop().unwrap()
    }

    /// All layer activations `[input, hidden..., output]` (the forward
    /// pass the backprop step consumes).
    fn activations(&self, x: &[f64]) -> Vec<Vec<f64>> {
        assert_eq!(x.len(), self.in_dim(), "mlp input dimensionality");
        let last = self.weights.len() - 1;
        let mut acts = vec![x.to_vec()];
        for (l, (w, b)) in self.weights.iter().zip(&self.biases).enumerate() {
            let mut z = vec![0.0; w.rows];
            w.matvec(acts.last().unwrap(), &mut z);
            for (zi, bi) in z.iter_mut().zip(b) {
                *zi += bi;
                if l < last {
                    *zi = zi.tanh();
                }
            }
            acts.push(z);
        }
        acts
    }

    /// Mean squared error over a dataset (averaged over rows and output
    /// dimensions).
    pub fn mse(&self, xs: &[Vec<f64>], ys: &[Vec<f64>]) -> f64 {
        if xs.is_empty() {
            return 0.0;
        }
        let mut total = 0.0;
        for (x, y) in xs.iter().zip(ys) {
            let p = self.forward(x);
            total += p
                .iter()
                .zip(y)
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f64>()
                / p.len() as f64;
        }
        total / xs.len() as f64
    }

    /// Accumulate MSE gradients for one sample into `grads` (same shapes
    /// as the parameters). Returns nothing; caller owns the averaging.
    fn backprop(&self, x: &[f64], y: &[f64], grads: &mut Grads) {
        let acts = self.activations(x);
        let out = acts.last().unwrap();
        // dL/dz at the linear output head, L = mean squared error
        let mut delta: Vec<f64> = out
            .iter()
            .zip(y)
            .map(|(a, b)| 2.0 * (a - b) / y.len() as f64)
            .collect();
        for l in (0..self.weights.len()).rev() {
            grads.w[l].add_outer(1.0, &delta, &acts[l]);
            for (g, d) in grads.b[l].iter_mut().zip(&delta) {
                *g += d;
            }
            if l > 0 {
                let mut prev = vec![0.0; self.sizes[l]];
                self.weights[l].matvec_transposed(&delta, &mut prev);
                // tanh'(z) = 1 - a², with a the stored activation
                for (p, a) in prev.iter_mut().zip(&acts[l]) {
                    *p *= 1.0 - a * a;
                }
                delta = prev;
            }
        }
    }

    /// Minibatch SGD: `opts.epochs` passes over the data, shuffled per
    /// epoch from `rng`.
    pub fn fit_sgd(&mut self, xs: &[Vec<f64>], ys: &[Vec<f64>], opts: &FitOpts, rng: &mut Pcg) {
        self.fit(xs, ys, opts, rng, &mut |mlp, grads, lr, _t| {
            mlp.apply(grads, |g, _slot| -lr * g);
        });
    }

    /// Minibatch Adam (Kingma & Ba 2015; β₁ = 0.9, β₂ = 0.999): the
    /// moment vectors live for this call only — training is restarted
    /// from scratch whenever the surrogate refits, so they never need to
    /// serialize.
    pub fn fit_adam(&mut self, xs: &[Vec<f64>], ys: &[Vec<f64>], opts: &FitOpts, rng: &mut Pcg) {
        let (b1, b2, eps) = (0.9, 0.999, 1e-8);
        let mut m: Vec<f64> = vec![0.0; self.param_count()];
        let mut v: Vec<f64> = vec![0.0; self.param_count()];
        self.fit(xs, ys, opts, rng, &mut |mlp, grads, lr, t| {
            let (bc1, bc2) = (1.0 - b1.powi(t), 1.0 - b2.powi(t));
            mlp.apply(grads, |g, slot| {
                m[slot] = b1 * m[slot] + (1.0 - b1) * g;
                v[slot] = b2 * v[slot] + (1.0 - b2) * g * g;
                -lr * (m[slot] / bc1) / ((v[slot] / bc2).sqrt() + eps)
            });
        });
    }

    /// The shared minibatch loop: shuffle, accumulate averaged gradients,
    /// hand them to `update(self, grads, lr, step)`.
    fn fit(
        &mut self,
        xs: &[Vec<f64>],
        ys: &[Vec<f64>],
        opts: &FitOpts,
        rng: &mut Pcg,
        update: &mut dyn FnMut(&mut Mlp, &Grads, f64, i32),
    ) {
        assert_eq!(xs.len(), ys.len(), "mlp fit: xs/ys length mismatch");
        if xs.is_empty() {
            return;
        }
        let batch = opts.batch.max(1).min(xs.len());
        let mut order: Vec<usize> = (0..xs.len()).collect();
        let mut step = 0i32;
        for _ in 0..opts.epochs {
            rng.shuffle(&mut order);
            for chunk in order.chunks(batch) {
                let mut grads = Grads::zeros(self);
                for &i in chunk {
                    self.backprop(&xs[i], &ys[i], &mut grads);
                }
                grads.scale(1.0 / chunk.len() as f64);
                step += 1;
                update(self, &grads, opts.lr, step);
            }
        }
    }

    /// Apply a per-parameter update: `delta(grad, flat_slot)` is added to
    /// each parameter, with slots numbered in the same order as
    /// [`Mlp::params`].
    fn apply(&mut self, grads: &Grads, mut delta: impl FnMut(f64, usize) -> f64) {
        let mut slot = 0;
        for (w, gw) in self.weights.iter_mut().zip(&grads.w) {
            for (p, g) in w.data.iter_mut().zip(&gw.data) {
                *p += delta(*g, slot);
                slot += 1;
            }
        }
        for (b, gb) in self.biases.iter_mut().zip(&grads.b) {
            for (p, g) in b.iter_mut().zip(gb) {
                *p += delta(*g, slot);
                slot += 1;
            }
        }
    }

    /// Total parameter count (weights + biases).
    pub fn param_count(&self) -> usize {
        self.weights.iter().map(|w| w.data.len()).sum::<usize>()
            + self.biases.iter().map(|b| b.len()).sum::<usize>()
    }

    /// Flatten all parameters (weights layer-by-layer, then biases) for
    /// serialization.
    pub fn params(&self) -> Vec<f64> {
        let mut out = Vec::with_capacity(self.param_count());
        for w in &self.weights {
            out.extend_from_slice(&w.data);
        }
        for b in &self.biases {
            out.extend_from_slice(b);
        }
        out
    }

    /// Restore parameters from [`Mlp::params`] output; `false` when the
    /// length does not match this architecture.
    pub fn set_params(&mut self, params: &[f64]) -> bool {
        if params.len() != self.param_count() {
            return false;
        }
        let mut it = params.iter();
        for w in &mut self.weights {
            for p in &mut w.data {
                *p = *it.next().unwrap();
            }
        }
        for b in &mut self.biases {
            for p in b.iter_mut() {
                *p = *it.next().unwrap();
            }
        }
        true
    }
}

/// Per-layer gradient accumulators, shaped like the parameters.
struct Grads {
    w: Vec<Matrix>,
    b: Vec<Vec<f64>>,
}

impl Grads {
    fn zeros(mlp: &Mlp) -> Grads {
        Grads {
            w: mlp
                .weights
                .iter()
                .map(|w| Matrix::zeros(w.rows, w.cols))
                .collect(),
            b: mlp.biases.iter().map(|b| vec![0.0; b.len()]).collect(),
        }
    }

    fn scale(&mut self, s: f64) {
        for w in &mut self.w {
            for v in &mut w.data {
                *v *= s;
            }
        }
        for b in &mut self.b {
            for v in b.iter_mut() {
                *v *= s;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// y = (x0 - 0.3)² + (x1 - 0.7)² on a grid — the same quadratic bowl
    /// shape the exploration surrogate has to learn.
    fn bowl_data() -> (Vec<Vec<f64>>, Vec<Vec<f64>>) {
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for i in 0..8 {
            for j in 0..8 {
                let (x0, x1) = (i as f64 / 7.0, j as f64 / 7.0);
                xs.push(vec![x0, x1]);
                ys.push(vec![(x0 - 0.3) * (x0 - 0.3) + (x1 - 0.7) * (x1 - 0.7)]);
            }
        }
        (xs, ys)
    }

    #[test]
    fn adam_learns_a_quadratic_bowl() {
        let (xs, ys) = bowl_data();
        let mut rng = Pcg::new(7);
        let mut mlp = Mlp::new(&[2, 16, 1], &mut rng);
        let before = mlp.mse(&xs, &ys);
        let opts = FitOpts {
            epochs: 200,
            ..Default::default()
        };
        mlp.fit_adam(&xs, &ys, &opts, &mut rng);
        let after = mlp.mse(&xs, &ys);
        assert!(after < before * 0.05, "mse {before} -> {after}");
        // the learned surface ranks the minimum below a far corner
        let near = mlp.forward(&[0.3, 0.7])[0];
        let far = mlp.forward(&[1.0, 0.0])[0];
        assert!(near < far, "near={near} far={far}");
    }

    #[test]
    fn sgd_reduces_loss() {
        let (xs, ys) = bowl_data();
        let mut rng = Pcg::new(3);
        let mut mlp = Mlp::new(&[2, 12, 1], &mut rng);
        let before = mlp.mse(&xs, &ys);
        let opts = FitOpts {
            epochs: 150,
            lr: 0.05,
            ..Default::default()
        };
        mlp.fit_sgd(&xs, &ys, &opts, &mut rng);
        assert!(mlp.mse(&xs, &ys) < before * 0.5);
    }

    #[test]
    fn training_is_bit_deterministic_for_a_fixed_seed() {
        let (xs, ys) = bowl_data();
        let run = || {
            let mut rng = Pcg::new(0xD5E);
            let mut mlp = Mlp::new(&[2, 8, 1], &mut rng);
            mlp.fit_adam(&xs, &ys, &FitOpts::default(), &mut rng);
            mlp.params()
        };
        let (a, b) = (run(), run());
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn params_roundtrip_preserves_predictions() {
        let mut rng = Pcg::new(11);
        let mlp = Mlp::new(&[3, 5, 2], &mut rng);
        let mut restored = Mlp::new(&[3, 5, 2], &mut rng); // different init
        assert!(restored.set_params(&mlp.params()));
        let x = [0.1, 0.5, 0.9];
        let (a, b) = (mlp.forward(&x), restored.forward(&x));
        for (u, v) in a.iter().zip(&b) {
            assert_eq!(u.to_bits(), v.to_bits());
        }
        assert!(!restored.set_params(&[0.0; 3]), "wrong length rejected");
        assert_eq!(mlp.param_count(), 3 * 5 + 5 + 5 * 2 + 2);
    }

    #[test]
    fn multi_output_head_fits_both_targets() {
        // y = [x, 1 - x]: two linear targets, one shared trunk
        let xs: Vec<Vec<f64>> = (0..16).map(|i| vec![i as f64 / 15.0]).collect();
        let ys: Vec<Vec<f64>> = xs.iter().map(|x| vec![x[0], 1.0 - x[0]]).collect();
        let mut rng = Pcg::new(5);
        let mut mlp = Mlp::new(&[1, 8, 2], &mut rng);
        let opts = FitOpts {
            epochs: 300,
            ..Default::default()
        };
        mlp.fit_adam(&xs, &ys, &opts, &mut rng);
        assert!(mlp.mse(&xs, &ys) < 1e-3);
    }

    #[test]
    fn empty_dataset_is_a_no_op() {
        let mut rng = Pcg::new(1);
        let mut mlp = Mlp::new(&[2, 4, 1], &mut rng);
        let before = mlp.params();
        mlp.fit_adam(&[], &[], &FitOpts::default(), &mut rng);
        assert_eq!(mlp.params(), before);
        assert_eq!(mlp.mse(&[], &[]), 0.0);
    }
}
