//! Mapping-strategy search built from the Table-1 primitives (paper §5.2).
//!
//! The original hand-coded searchers are now thin deprecated shims over
//! the [`explore`](super::explore) API:
//!
//! * [`greedy_tiling`] — graph-transformation search, ported as
//!   [`TilingSpace`] (one `rounds` axis whose value applies that many
//!   greedy split-and-spread rounds) climbed by
//!   [`HillClimbExplorer`](super::explore::HillClimbExplorer).
//! * [`anneal_placement`] — task-assignment search, ported as
//!   [`PlacementSpace`](super::explore::PlacementSpace) driven by
//!   [`AnnealExplorer`](super::explore::AnnealExplorer).

use crate::eval::Registry;
use crate::hwir::{Hardware, PointId};
use crate::mapping::MappingState;
use crate::sim::SimConfig;
use crate::util::error::Result;

use super::explore::{
    explore, AnnealExplorer, Axis, AxisKind, Candidate, Design, DesignSpace, ExploreOpts,
    HillClimbExplorer, Makespan, Objective, PlacementSpace,
};
use crate::workloads::Workload;

/// Search configuration.
#[derive(Debug, Clone)]
pub struct SearchConfig {
    pub seed: u64,
    /// Annealing iterations.
    pub iters: usize,
    /// Initial temperature as a fraction of the initial makespan.
    pub init_temp: f64,
}

impl Default for SearchConfig {
    fn default() -> Self {
        SearchConfig {
            seed: 0xD5E,
            iters: 60,
            init_temp: 0.1,
        }
    }
}

/// One greedy tiling round: split the most expensive enabled compute task
/// 2-way and spread the halves over the two least-loaded compute points.
/// Returns false when no task can be split.
fn greedy_round(hw: &Hardware, state: &mut MappingState, evals: &Registry) -> bool {
    let compute_points = hw.points_of_kind("compute");
    let heaviest = state
        .graph
        .iter()
        .filter(|t| t.enabled && t.kind.is_compute())
        .max_by(|a, b| {
            let da = evals
                .demand(a, hw.entry(state.mapping.point_of(a.id).unwrap()))
                .total();
            let db = evals
                .demand(b, hw.entry(state.mapping.point_of(b.id).unwrap()))
                .total();
            da.total_cmp(&db)
        })
        .map(|t| t.id);
    let Some(task) = heaviest else {
        return false;
    };
    let Ok(tiles) = state.tile_task(task, &[2]) else {
        return false;
    };
    let mut load: Vec<(PointId, usize)> = compute_points
        .iter()
        .map(|p| (*p, state.mapping.tasks_on(*p).len()))
        .collect();
    load.sort_by_key(|(_, l)| *l);
    for (tile, (p, _)) in tiles.iter().zip(load.iter()) {
        state.map_node(*tile, *p).ok();
    }
    true
}

/// Graph-transformation design space: a single `rounds` axis whose value
/// `k` means "apply `k` greedy tiling rounds to the base mapping state".
/// Hill-climbing from `rounds = 0` reproduces the legacy greedy search,
/// which stopped at the first non-improving round.
pub struct TilingSpace<'a> {
    hw: &'a Hardware,
    evals: &'a Registry,
    base: &'a MappingState,
    axes: Vec<Axis>,
}

impl<'a> TilingSpace<'a> {
    pub fn new(
        hw: &'a Hardware,
        evals: &'a Registry,
        base: &'a MappingState,
        max_rounds: usize,
    ) -> TilingSpace<'a> {
        let rounds: Vec<u64> = (0..=max_rounds as u64).collect();
        TilingSpace {
            hw,
            evals,
            base,
            axes: vec![Axis::u64s("rounds", AxisKind::Mapping, &rounds)],
        }
    }

    /// Rebuild the base state and apply `k` greedy rounds to it.
    fn expanded(&self, k: usize) -> MappingState {
        let mut state = MappingState::new(self.base.graph.clone());
        state.mapping = self.base.mapping.clone();
        for _ in 0..k {
            if !greedy_round(self.hw, &mut state, self.evals) {
                break;
            }
        }
        state
    }

    /// Apply candidate `c`'s rounds to an external state (used by the
    /// legacy shim to update the caller's `MappingState` in place).
    pub fn apply(&self, c: &Candidate, state: &mut MappingState) {
        for _ in 0..c.0[0] {
            if !greedy_round(self.hw, state, self.evals) {
                break;
            }
        }
    }
}

impl DesignSpace for TilingSpace<'_> {
    fn name(&self) -> &str {
        "greedy-tiling"
    }

    fn axes(&self) -> &[Axis] {
        &self.axes
    }

    fn materialize(&self, c: &Candidate) -> Result<Design> {
        crate::ensure!(self.in_bounds(c), "candidate out of bounds for tiling space");
        let state = self.expanded(c.0[0] as usize);
        Ok(Design::new(Workload {
            hw: self.hw.clone(),
            graph: state.graph,
            mapping: state.mapping,
            name: "greedy-tiling".into(),
            notes: Vec::new(),
        }))
    }
}

/// Greedy tiling search: split the most expensive compute task 2-way
/// (distributing the halves over the least-loaded compute points) while
/// the makespan improves. Returns the best makespan found and leaves
/// `state` at the best round count.
#[deprecated(note = "use dse::explore with TilingSpace + HillClimbExplorer")]
pub fn greedy_tiling(
    hw: &Hardware,
    state: &mut MappingState,
    evals: &Registry,
    sim_cfg: &SimConfig,
    max_rounds: usize,
) -> f64 {
    let space = TilingSpace::new(hw, evals, state, max_rounds);
    let objectives: Vec<Box<dyn Objective>> = vec![Box::new(Makespan)];
    let opts = ExploreOpts {
        budget: 2 * (max_rounds + 1),
        workers: 1,
        sim: sim_cfg.clone(),
        ..Default::default()
    };
    let explorer = HillClimbExplorer {
        seed: 0,
        from_initial: true,
        restarts: false,
    };
    let Ok(report) = explore(&space, &objectives, &explorer, evals, &opts) else {
        return f64::INFINITY;
    };
    let Some(best) = report.best() else {
        return f64::INFINITY;
    };
    let best_score = best.objectives[0];
    let rounds = best.candidate.0[0] as usize;
    // drop the space's borrow of `state` before replaying the winning
    // round count onto the caller's state
    drop(report);
    drop(space);
    for _ in 0..rounds {
        if !greedy_round(hw, state, evals) {
            break;
        }
    }
    best_score
}

/// Simulated-annealing placement search over `map_node` moves.
/// Returns (best makespan, accepted moves) and leaves `state` at the best
/// placement found.
#[deprecated(note = "use dse::explore with PlacementSpace + AnnealExplorer")]
pub fn anneal_placement(
    hw: &Hardware,
    state: &mut MappingState,
    evals: &Registry,
    sim_cfg: &SimConfig,
    cfg: &SearchConfig,
) -> (f64, usize) {
    let space = PlacementSpace::new(
        "anneal-placement",
        hw.clone(),
        state.graph.clone(),
        state.mapping.clone(),
    );
    let objectives: Vec<Box<dyn Objective>> = vec![Box::new(Makespan)];
    let opts = ExploreOpts {
        budget: cfg.iters + 1,
        workers: 1,
        sim: sim_cfg.clone(),
        ..Default::default()
    };
    let explorer = AnnealExplorer {
        seed: cfg.seed,
        init_temp: cfg.init_temp,
    };
    let Ok(report) = explore(&space, &objectives, &explorer, evals, &opts) else {
        return (f64::INFINITY, 0);
    };
    let Some(best) = report.best() else {
        return (f64::INFINITY, 0);
    };
    space.apply(&best.candidate, &mut state.mapping);
    (best.objectives[0], report.moves_accepted)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hwir::{
        ComputeAttrs, Coord, Element, MemoryAttrs, SpaceMatrix, SpacePoint,
    };
    use crate::sim::simulate;
    use crate::taskgraph::{ComputeCost, OpClass, TaskGraph, TaskKind};

    fn hw(cores: usize) -> Hardware {
        let mut m = SpaceMatrix::new("chip", vec![cores]);
        for i in 0..cores {
            m.set(
                Coord::new(vec![i as u32]),
                Element::Point(SpacePoint::compute(
                    "core",
                    ComputeAttrs::new((8, 8), 32).with_lmem(MemoryAttrs::new(1 << 20, 512.0, 1)),
                )),
            );
        }
        Hardware::build(m)
    }

    fn all_on_one_core(n_tasks: usize, hw: &Hardware) -> MappingState {
        let mut g = TaskGraph::new();
        let core = hw.points_of_kind("compute")[0];
        for i in 0..n_tasks {
            let mut c = ComputeCost::zero(OpClass::Elementwise);
            c.vec_flops = 64_000.0;
            g.add(format!("t{i}"), TaskKind::Compute(c));
        }
        let mut st = MappingState::new(g);
        for t in st.graph.ids().collect::<Vec<_>>() {
            st.map_node(t, core).unwrap();
        }
        st
    }

    fn makespan(
        hw: &Hardware,
        state: &MappingState,
        evals: &Registry,
        sim_cfg: &SimConfig,
    ) -> Option<f64> {
        simulate(hw, &state.graph, &state.mapping, evals, sim_cfg)
            .ok()
            .map(|r| r.makespan)
    }

    #[test]
    #[allow(deprecated)]
    fn anneal_improves_degenerate_placement() {
        // 8 independent tasks all on one of 4 cores: annealing must spread
        // them and cut the makespan.
        let hw = hw(4);
        let mut st = all_on_one_core(8, &hw);
        let evals = Registry::standard();
        let sim_cfg = SimConfig::default();
        let before = makespan(&hw, &st, &evals, &sim_cfg).unwrap();
        let (best, accepted) = anneal_placement(
            &hw,
            &mut st,
            &evals,
            &sim_cfg,
            &SearchConfig {
                iters: 80,
                ..Default::default()
            },
        );
        assert!(accepted > 0);
        assert!(
            best < before * 0.6,
            "anneal failed to improve: {before} -> {best}"
        );
        // the caller's state now carries the best placement found
        let after = makespan(&hw, &st, &evals, &sim_cfg).unwrap();
        assert!((after - best).abs() / best < 1e-9, "{after} vs {best}");
    }

    #[test]
    #[allow(deprecated)]
    fn greedy_tiling_splits_heavy_task() {
        let hw = hw(4);
        let mut g = TaskGraph::new();
        let mut c = ComputeCost::zero(OpClass::Elementwise);
        c.vec_flops = 1_000_000.0;
        let t = g.add("big", TaskKind::Compute(c));
        let mut st = MappingState::new(g);
        st.map_node(t, hw.points_of_kind("compute")[0]).unwrap();
        let evals = Registry::standard();
        let sim_cfg = SimConfig::default();
        let before = makespan(&hw, &st, &evals, &sim_cfg).unwrap();
        let best = greedy_tiling(&hw, &mut st, &evals, &sim_cfg, 3);
        assert!(best < before, "{before} -> {best}");
        // state was advanced to the winning round count
        let after = makespan(&hw, &st, &evals, &sim_cfg).unwrap();
        assert!((after - best).abs() / best < 1e-9, "{after} vs {best}");
    }

    #[test]
    fn tiling_space_round_zero_is_identity() {
        let hw = hw(2);
        let st = all_on_one_core(2, &hw);
        let evals = Registry::standard();
        let space = TilingSpace::new(&hw, &evals, &st, 2);
        assert_eq!(space.size(), 3);
        let d = space.materialize(&Candidate(vec![0])).unwrap();
        assert_eq!(d.workload.graph.len(), st.graph.len());
        let d1 = space.materialize(&Candidate(vec![1])).unwrap();
        // one round replaces a task with two tiles
        assert_eq!(d1.workload.graph.len(), st.graph.len() + 1);
    }
}
