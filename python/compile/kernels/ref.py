"""Pure-jnp oracle for the batched roofline evaluator (Layer-1 correctness
reference).

This mirrors, bit-for-bit in semantics, the Rust default evaluator
(`rust/src/eval/roofline.rs`) and is the ground truth the Pallas kernel
(`roofline.py`) is checked against by pytest + hypothesis.

Descriptor layout (one row per task, must match `rust/src/eval/pjrt.rs`):

    0: op code          4: out_bytes
    1: mac_flops        5: m
    2: vec_flops        6: n
    3: in_bytes         7: k

Hardware-parameter vector:

    0: systolic rows R      4: lmem latency
    1: systolic cols C      5: pipeline fill factor
    2: vector lanes         6: vector efficiency
    3: lmem bandwidth
"""

import jax.numpy as jnp

# Op codes (must match rust `OpClass::code`).
OP_MATMUL = 0
OP_MVM = 1
OP_SOFTMAX = 2
OP_LAYERNORM = 3
OP_ELEMENTWISE = 4
OP_ATTENTION = 5
OP_ROPE = 6
OP_CUSTOM = 7

DESC_FIELDS = 8
HW_FIELDS = 7

_INF = jnp.float32(jnp.inf)


def matrix_cycles(mac_flops, m, n, k, rows, cols, fill):
    """Tile-quantized systolic-array cycles (see RooflineEvaluator)."""
    area = 2.0 * rows * cols
    # fallback when dims are unknown: ideal throughput
    ideal = mac_flops / jnp.maximum(area, 1.0)
    waves = jnp.ceil(m / jnp.maximum(rows, 1.0)) * jnp.ceil(n / jnp.maximum(cols, 1.0))
    quant = waves * (k + fill * (rows + cols))
    cyc = jnp.where(m * n * k == 0.0, ideal, quant)
    cyc = jnp.where(rows * cols == 0.0, _INF, cyc)  # matrix work, no array
    return jnp.where(mac_flops <= 0.0, 0.0, cyc)


def vector_cycles(vec_flops, op, lanes, veff):
    eff = jnp.where((op == OP_SOFTMAX) | (op == OP_LAYERNORM), veff, 1.0)
    denom = 2.0 * lanes * eff
    cyc = jnp.where(denom > 0.0, vec_flops / jnp.maximum(denom, 1e-30), _INF)
    return jnp.where(vec_flops <= 0.0, 0.0, cyc)


def evaluate_ref(desc, hw):
    """Reference batched evaluation.

    Args:
      desc: f32[B, 8] task descriptors.
      hw:   f32[7] hardware parameters.

    Returns:
      f32[B] latency in cycles.
    """
    desc = jnp.asarray(desc, jnp.float32)
    hw = jnp.asarray(hw, jnp.float32)
    op = desc[:, 0]
    mac_flops = desc[:, 1]
    vec_flops = desc[:, 2]
    in_bytes = desc[:, 3]
    out_bytes = desc[:, 4]
    m, n, k = desc[:, 5], desc[:, 6], desc[:, 7]
    rows, cols, lanes, bw, lat, fill, veff = (hw[i] for i in range(HW_FIELDS))

    mat = matrix_cycles(mac_flops, m, n, k, rows, cols, fill)
    vec = vector_cycles(vec_flops, op, lanes, veff)
    mem = jnp.where(jnp.isinf(bw), 0.0, (in_bytes + out_bytes) / jnp.maximum(bw, 1e-30))
    return lat + jnp.maximum(mat + vec, mem)
