//! The design-space algebra: composition combinators over
//! [`DesignSpace`].
//!
//! * [`ProductSpace`] composes heterogeneous spaces **side by side**:
//!   digit vectors concatenate and every axis keeps its sub-space's
//!   [`AxisKind`] tier. The first sub-space materializes the design; each
//!   later sub *refines* it ([`DesignSpace::refine`] — e.g. a
//!   [`ProgramSpace`] replaying a mapping program on the materialized
//!   workload).
//! * [`NestedSpace`] composes **conditionally**: an outer (architecture /
//!   packaging) candidate *instantiates* the inner (hw-param / mapping)
//!   space through a factory, and the outer digits become the natural
//!   [`DesignSpace::topology_key`] prefix — a joint three-tier search
//!   builds one `EvalPlan` (hardware model + interned route table +
//!   simulator arenas) per distinct outer candidate and rebinds only the
//!   mapping inside it.
//!
//! Both combinators — and the mapping programs they embed — are
//! JSON-definable ([`space_from_json`]), so `mldse explore --space
//! FILE.json` can drive a composed three-tier search from a file. The
//! paper's §7 narrative (architecture × hardware parameter × mapping,
//! jointly) is packaged as [`three_tier`], reachable as the `three-tier`
//! preset and experiment.

use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock};

use crate::mapping::{placement_program, MappingProgram};
use crate::util::error::{Context, Result};
use crate::util::json::Json;

use super::objective::{AreaConstrainedMakespan, CostUsd, Edp, Makespan, Objective};
use super::program::ProgramSpace;
use super::space::{
    Axis, AxisKind, Binding, Candidate, Design, DesignSpace, PackagingSpace, ParamSpace,
};

/// A boxed design space that can cross worker threads (all composition
/// combinators store and return these).
pub type BoxSpace = Box<dyn DesignSpace + Send>;

// ======================================================================
// ProductSpace
// ======================================================================

/// Side-by-side composition: `subs[0]` materializes, `subs[1..]` refine.
///
/// Axes concatenate in sub order (names prefixed `"{sub}.{axis}"` so
/// labels stay unambiguous); a candidate splits positionally back into
/// per-sub candidates.
type BaseResult = std::result::Result<Arc<Design>, String>;

pub struct ProductSpace {
    name: String,
    subs: Vec<BoxSpace>,
    axes: Vec<Axis>,
    /// `offsets[i]..offsets[i+1]` is sub `i`'s digit range.
    offsets: Vec<usize>,
    /// `subs[0]` designs cached per sub-0 candidate, so keyed rebinds
    /// ([`DesignSpace::bind`]) clone instead of re-materializing the
    /// hardware. Only the bind path populates it — bind runs only for
    /// topology-keyed candidates, whose distinct sub-0 digits are bounded
    /// by the distinct keys of the search.
    base_cache: Mutex<HashMap<Vec<u32>, Arc<OnceLock<BaseResult>>>>,
}

impl ProductSpace {
    pub fn new(name: &str, subs: Vec<BoxSpace>) -> Result<ProductSpace> {
        crate::ensure!(!subs.is_empty(), "product space '{name}' has no sub-spaces");
        let mut axes = Vec::new();
        let mut offsets = Vec::with_capacity(subs.len() + 1);
        offsets.push(0);
        for sub in &subs {
            for a in sub.axes() {
                axes.push(Axis {
                    name: format!("{}.{}", sub.name(), a.name),
                    kind: a.kind,
                    values: a.values.clone(),
                });
            }
            offsets.push(axes.len());
        }
        Ok(ProductSpace {
            name: name.to_string(),
            subs,
            axes,
            offsets,
            base_cache: Mutex::new(HashMap::new()),
        })
    }

    /// The composed sub-spaces, in digit order.
    pub fn subs(&self) -> &[BoxSpace] {
        &self.subs
    }

    /// Split a product candidate into per-sub candidates.
    pub fn split(&self, c: &Candidate) -> Vec<Candidate> {
        (0..self.subs.len())
            .map(|i| Candidate(c.0[self.offsets[i]..self.offsets[i + 1]].to_vec()))
            .collect()
    }

    /// The cached `subs[0]` design for one sub-0 candidate (built exactly
    /// once, shared across worker threads).
    fn base_for(&self, part0: &Candidate) -> BaseResult {
        let cell = {
            let mut cache = self.base_cache.lock().expect("product cache poisoned");
            Arc::clone(cache.entry(part0.0.clone()).or_default())
        };
        cell.get_or_init(|| {
            self.subs[0]
                .materialize(part0)
                .map(Arc::new)
                .map_err(|e| format!("{e:#}"))
        })
        .clone()
    }
}

impl DesignSpace for ProductSpace {
    fn name(&self) -> &str {
        &self.name
    }

    fn axes(&self) -> &[Axis] {
        &self.axes
    }

    fn initial(&self) -> Candidate {
        let mut digits = Vec::with_capacity(self.axes.len());
        for sub in &self.subs {
            digits.extend(sub.initial().0);
        }
        Candidate(digits)
    }

    fn materialize(&self, c: &Candidate) -> Result<Design> {
        crate::ensure!(self.in_bounds(c), "candidate out of bounds for '{}'", self.name);
        let parts = self.split(c);
        let mut design = self.subs[0]
            .materialize(&parts[0])
            .with_context(|| format!("product '{}' sub '{}'", self.name, self.subs[0].name()))?;
        for (sub, part) in self.subs.iter().zip(&parts).skip(1) {
            design = sub
                .refine(design, part)
                .with_context(|| format!("product '{}' sub '{}'", self.name, sub.name()))?;
        }
        Ok(design)
    }

    /// Composition rule: a sub with its own topology key contributes that
    /// key; a key-less sub with no mapping-tier axes is hardware-defining
    /// and contributes its full digits; a key-less sub *with* mapping
    /// axes (e.g. a tiling program) forfeits sharing for the whole
    /// product. Contributions are length-prefixed so concatenation stays
    /// injective. All-key-less products stay key-less (ephemeral setups).
    fn topology_key(&self, c: &Candidate) -> Option<Vec<u32>> {
        let parts = self.split(c);
        let mut contributions = Vec::with_capacity(self.subs.len());
        let mut any_keyed = false;
        for (sub, part) in self.subs.iter().zip(&parts) {
            match sub.topology_key(part) {
                Some(k) => {
                    any_keyed = true;
                    contributions.push(k);
                }
                None => {
                    if sub.axes().iter().any(|a| a.kind == AxisKind::Mapping) {
                        return None;
                    }
                    contributions.push(part.0.clone());
                }
            }
        }
        if !any_keyed {
            return None;
        }
        let mut key = Vec::new();
        for k in contributions {
            key.push(k.len() as u32);
            key.extend(k);
        }
        Some(key)
    }

    /// Keyed rebinding: clone the cached `subs[0]` design and replay only
    /// the refinement subs, instead of re-materializing the hardware per
    /// candidate.
    fn bind(&self, c: &Candidate) -> Result<Binding> {
        crate::ensure!(self.in_bounds(c), "candidate out of bounds for '{}'", self.name);
        let parts = self.split(c);
        let base = self
            .base_for(&parts[0])
            .map_err(|msg| crate::format_err!("{msg}"))?;
        let mut design = (*base).clone();
        for (sub, part) in self.subs.iter().zip(&parts).skip(1) {
            design = sub
                .refine(design, part)
                .with_context(|| format!("product '{}' sub '{}'", self.name, sub.name()))?;
        }
        Ok(Binding::of(design))
    }
}

// ======================================================================
// NestedSpace
// ======================================================================

/// Builds the inner space for one outer candidate (receives the outer
/// candidate and its materialized design).
pub type InnerFactory = Box<dyn Fn(&Candidate, &Design) -> Result<BoxSpace> + Send + Sync>;

struct InnerEntry {
    space: BoxSpace,
    /// Side figures of the *outer* design, inherited by every nested
    /// candidate whose inner design does not supply its own.
    area_mm2: Option<f64>,
    cost_usd: Option<f64>,
}

type InnerResult = std::result::Result<Arc<InnerEntry>, String>;

/// Conditional composition: `outer` picks an architecture point, the
/// factory instantiates the inner space over its materialized design,
/// and the joint candidate is `[outer digits ++ inner digits]`.
///
/// The inner space's *shape* (axis count and cardinalities) must not vary
/// across outer candidates — the factory output is checked against the
/// template instantiated from `outer.initial()`. Inner instances are
/// cached per outer candidate (built exactly once, shared across worker
/// threads), and [`DesignSpace::topology_key`] prefixes the inner key
/// with the outer digits, so a topology-keyed engine builds one
/// evaluation setup per distinct outer point.
pub struct NestedSpace {
    name: String,
    outer: BoxSpace,
    factory: InnerFactory,
    axes: Vec<Axis>,
    n_outer: usize,
    inner_initial: Vec<u32>,
    cache: Mutex<HashMap<Vec<u32>, Arc<OnceLock<InnerResult>>>>,
}

impl NestedSpace {
    pub fn new(name: &str, outer: BoxSpace, factory: InnerFactory) -> Result<NestedSpace> {
        let outer_initial = outer.initial();
        let design = outer.materialize(&outer_initial).with_context(|| {
            format!("nested '{name}': materializing the outer initial candidate for the template")
        })?;
        let template = factory(&outer_initial, &design)
            .with_context(|| format!("nested '{name}': instantiating the inner template"))?;
        let mut axes = outer.axes().to_vec();
        axes.extend(template.axes().to_vec());
        let n_outer = outer.axes().len();
        let inner_initial = template.initial().0;
        let entry = Arc::new(InnerEntry {
            space: template,
            area_mm2: design.area_mm2,
            cost_usd: design.cost_usd,
        });
        let seeded = Arc::new(OnceLock::new());
        let set = seeded.set(Ok(entry));
        debug_assert!(set.is_ok(), "freshly created cell");
        let cache = Mutex::new(HashMap::from([(outer_initial.0, seeded)]));
        Ok(NestedSpace {
            name: name.to_string(),
            outer,
            factory,
            axes,
            n_outer,
            inner_initial,
            cache,
        })
    }

    /// Nest a mapping program: the inner space is a [`ProgramSpace`]
    /// replaying `program` on whatever workload the outer candidate
    /// materializes (`ComputePoints` hole domains resolve against that
    /// hardware).
    pub fn with_program(
        name: &str,
        outer: BoxSpace,
        program: MappingProgram,
    ) -> Result<NestedSpace> {
        let inner_name = format!("{name}.program");
        let factory: InnerFactory = Box::new(move |_outer_c, design: &Design| {
            let w = &design.workload;
            ProgramSpace::over(
                &inner_name,
                w.hw.clone(),
                w.graph.clone(),
                w.mapping.clone(),
                program.clone(),
            )
            .map(|s| Box::new(s) as BoxSpace)
        });
        NestedSpace::new(name, outer, factory)
    }

    /// The outer space.
    pub fn outer(&self) -> &dyn DesignSpace {
        self.outer.as_ref()
    }

    /// Number of leading digits that belong to the outer space.
    pub fn outer_digits(&self) -> usize {
        self.n_outer
    }

    fn entry_for(&self, outer_digits: &[u32]) -> InnerResult {
        let cell = {
            let mut cache = self.cache.lock().expect("nested cache poisoned");
            Arc::clone(cache.entry(outer_digits.to_vec()).or_default())
        };
        cell.get_or_init(|| {
            let outer_c = Candidate(outer_digits.to_vec());
            let design = self
                .outer
                .materialize(&outer_c)
                .map_err(|e| format!("{e:#}"))?;
            let space = (self.factory)(&outer_c, &design).map_err(|e| format!("{e:#}"))?;
            let template = &self.axes[self.n_outer..];
            let shape_ok = space.axes().len() == template.len()
                && space
                    .axes()
                    .iter()
                    .zip(template)
                    .all(|(a, t)| a.len() == t.len());
            if !shape_ok {
                return Err(format!(
                    "nested '{}': inner space shape for outer candidate {:?} does not match \
                     the template ({} axes of cardinalities {:?} expected)",
                    self.name,
                    outer_digits,
                    template.len(),
                    template.iter().map(Axis::len).collect::<Vec<_>>()
                ));
            }
            Ok(Arc::new(InnerEntry {
                space,
                area_mm2: design.area_mm2,
                cost_usd: design.cost_usd,
            }))
        })
        .clone()
    }

    fn split<'c>(&self, c: &'c Candidate) -> (&'c [u32], &'c [u32]) {
        c.0.split_at(self.n_outer)
    }
}

impl DesignSpace for NestedSpace {
    fn name(&self) -> &str {
        &self.name
    }

    fn axes(&self) -> &[Axis] {
        &self.axes
    }

    fn initial(&self) -> Candidate {
        let mut digits = self.outer.initial().0;
        digits.extend(&self.inner_initial);
        Candidate(digits)
    }

    fn materialize(&self, c: &Candidate) -> Result<Design> {
        crate::ensure!(self.in_bounds(c), "candidate out of bounds for '{}'", self.name);
        let (outer, inner) = self.split(c);
        let entry = self
            .entry_for(outer)
            .map_err(|msg| crate::format_err!("{msg}"))?;
        let mut design = entry.space.materialize(&Candidate(inner.to_vec()))?;
        design.area_mm2 = design.area_mm2.or(entry.area_mm2);
        design.cost_usd = design.cost_usd.or(entry.cost_usd);
        Ok(design)
    }

    /// `outer digits ++ inner key`: one shared evaluation setup per
    /// distinct outer candidate when the inner space is itself keyed
    /// (e.g. an assignment-only program). A key-less inner (tiling under
    /// a hole) makes the whole nested candidate key-less.
    fn topology_key(&self, c: &Candidate) -> Option<Vec<u32>> {
        if !self.in_bounds(c) {
            return None;
        }
        let (outer, inner) = self.split(c);
        let entry = self.entry_for(outer).ok()?;
        let inner_key = entry.space.topology_key(&Candidate(inner.to_vec()))?;
        let mut key = outer.to_vec();
        key.extend(inner_key);
        Some(key)
    }

    /// Inner-space rebinding against the cached instantiation: no outer
    /// re-materialization, no hardware clone.
    fn bind(&self, c: &Candidate) -> Result<Binding> {
        crate::ensure!(self.in_bounds(c), "candidate out of bounds for '{}'", self.name);
        let (outer, inner) = self.split(c);
        let entry = self
            .entry_for(outer)
            .map_err(|msg| crate::format_err!("{msg}"))?;
        let mut binding = entry.space.bind(&Candidate(inner.to_vec()))?;
        binding.area_mm2 = binding.area_mm2.or(entry.area_mm2);
        binding.cost_usd = binding.cost_usd.or(entry.cost_usd);
        Ok(binding)
    }
}

// ======================================================================
// The three-tier composed space (paper §7, end to end)
// ======================================================================

/// The paper's headline joint search as one composed space:
///
/// * **Architecture tier** — MPMC packaging technology (MCM vs 2.5D
///   interposer) and chiplets per package;
/// * **Hardware-parameter tier** — chiplet local-memory bandwidth under
///   the fixed paper templates;
/// * **Mapping tier** — a placement [`MappingProgram`] whose holes
///   re-place the heaviest decode tasks, instantiated per outer
///   candidate over the materialized MPMC workload.
///
/// Every candidate is one joint digit vector; the outer digits key the
/// shared evaluation setup, so the engine builds hardware + route table
/// once per distinct (packaging, cpp, lmem_bw) point.
pub fn three_tier(name: &str, quick: bool) -> Result<NestedSpace> {
    let lmem_bws: &[f64] = if quick {
        &[76.0, 304.0]
    } else {
        &[76.0, 152.0, 304.0]
    };
    let outer = PackagingSpace::paper_preset(name, quick).with_lmem_bw_axis(lmem_bws);
    let holes = if quick { 2 } else { 3 };
    NestedSpace::with_program(name, Box::new(outer), placement_program(holes))
}

// ======================================================================
// JSON space files
// ======================================================================

/// Parse a space file. Dispatches on `"type"`:
///
/// | `"type"` | space |
/// |---|---|
/// | `"param"` (or absent) | [`ParamSpace`] (DMC/GSM hw-param axes) |
/// | `"packaging"` | [`PackagingSpace`] (MPMC packaging × cpp × lmem_bw) |
/// | `"product"` | [`ProductSpace`] over `"subs"` (later subs refine) |
/// | `"nested"` | [`NestedSpace`] over `"outer"` + `"program"` |
/// | `"program"` | only valid *inside* `product`/`nested` |
pub fn space_from_json(text: &str) -> Result<BoxSpace> {
    let doc = Json::parse(text).context("parsing space file")?;
    space_from_json_value(&doc)
}

pub fn space_from_json_value(doc: &Json) -> Result<BoxSpace> {
    let ty = doc.get("type").and_then(|v| v.as_str()).unwrap_or("param");
    match ty {
        "param" => Ok(Box::new(ParamSpace::from_json_value(doc)?)),
        "packaging" => Ok(Box::new(PackagingSpace::from_json_value(doc)?)),
        "product" => {
            let name = doc
                .get("name")
                .and_then(|v| v.as_str())
                .unwrap_or("product")
                .to_string();
            let subs_json = doc
                .get("subs")
                .and_then(|v| v.as_arr())
                .context("a product space needs a \"subs\" array")?;
            let mut subs = Vec::with_capacity(subs_json.len());
            for (i, sub) in subs_json.iter().enumerate() {
                let space = if sub.get("type").and_then(|v| v.as_str()) == Some("program") {
                    crate::ensure!(
                        i > 0,
                        "product '{name}': the first sub must materialize a workload \
                         (a program space can only refine)"
                    );
                    Box::new(program_space_from_json(sub)?) as BoxSpace
                } else {
                    space_from_json_value(sub)
                        .with_context(|| format!("product '{name}' sub {i}"))?
                };
                subs.push(space);
            }
            Ok(Box::new(ProductSpace::new(&name, subs)?))
        }
        "nested" => {
            let name = doc
                .get("name")
                .and_then(|v| v.as_str())
                .unwrap_or("nested")
                .to_string();
            let outer_json = doc
                .get("outer")
                .context("a nested space needs an \"outer\" space object")?;
            let outer = space_from_json_value(outer_json)
                .with_context(|| format!("nested '{name}' outer"))?;
            let program_json = doc
                .get("program")
                .context("a nested space needs a \"program\" instruction array")?;
            let program = MappingProgram::from_json_value(program_json)
                .with_context(|| format!("nested '{name}' program"))?;
            Ok(Box::new(NestedSpace::with_program(&name, outer, program)?))
        }
        "program" => crate::bail!(
            "a top-level program space has no base workload to replay against; \
             use it as the inner of a \"nested\" space or a non-leading sub of a \
             \"product\""
        ),
        other => crate::bail!(
            "unknown space type '{other}' (valid: param, packaging, product, nested)"
        ),
    }
}

fn program_space_from_json(doc: &Json) -> Result<ProgramSpace> {
    let name = doc
        .get("name")
        .and_then(|v| v.as_str())
        .unwrap_or("program")
        .to_string();
    let program_json = doc
        .get("program")
        .context("a program space needs a \"program\" instruction array")?;
    let program = MappingProgram::from_json_value(program_json)
        .with_context(|| format!("program '{name}'"))?;
    ProgramSpace::floating(&name, program)
}

/// Parse the optional `"objectives"` list of a space file
/// (`["makespan", "edp", "cost_usd", "makespan@area<=900"]`); `None`
/// when the file does not specify objectives.
pub fn objectives_from_json(doc: &Json) -> Result<Option<Vec<Box<dyn Objective>>>> {
    let Some(list) = doc.get("objectives") else {
        return Ok(None);
    };
    let arr = list
        .as_arr()
        .context("\"objectives\" must be an array of names")?;
    crate::ensure!(!arr.is_empty(), "\"objectives\" must not be empty");
    let mut out: Vec<Box<dyn Objective>> = Vec::with_capacity(arr.len());
    for v in arr {
        let name = v.as_str().context("objective names must be strings")?;
        out.push(match name {
            "makespan" => Box::new(Makespan),
            "edp" => Box::new(Edp),
            "cost" | "cost_usd" => Box::new(CostUsd),
            other => match other.strip_prefix("makespan@area<=") {
                Some(budget) => {
                    let b: f64 = budget.parse().map_err(|_| {
                        crate::format_err!("objective '{other}': invalid area budget '{budget}'")
                    })?;
                    Box::new(AreaConstrainedMakespan::new(b))
                }
                None => crate::bail!(
                    "unknown objective '{other}' (valid: makespan, edp, cost_usd, \
                     makespan@area<=N)"
                ),
            },
        });
    }
    Ok(Some(out))
}

#[cfg(test)]
mod tests {
    use std::sync::atomic::{AtomicUsize, Ordering};

    use super::*;
    use crate::hwir::{
        ComputeAttrs, Coord, Element, Hardware, MemoryAttrs, SpaceMatrix, SpacePoint,
    };
    use crate::mapping::{Mapping, Param, Prim, TaskSel};
    use crate::taskgraph::{ComputeCost, OpClass, TaskGraph, TaskKind};
    use crate::workloads::Workload;

    fn tiny_hw(cores: usize) -> Hardware {
        let mut m = SpaceMatrix::new("chip", vec![cores]);
        for i in 0..cores {
            m.set(
                Coord::new(vec![i as u32]),
                Element::Point(SpacePoint::compute(
                    "core",
                    ComputeAttrs::new((8, 8), 32).with_lmem(MemoryAttrs::new(1 << 20, 512.0, 1)),
                )),
            );
        }
        Hardware::build(m)
    }

    /// A 1-axis Arch-tier outer space: the digit picks the number of
    /// tasks; all tasks start on core 0 of a fixed 4-core chip.
    struct TinyOuter {
        axes: Vec<Axis>,
    }

    impl TinyOuter {
        fn new() -> TinyOuter {
            TinyOuter {
                axes: vec![Axis::u64s("tasks", AxisKind::Arch, &[2, 3])],
            }
        }
    }

    impl DesignSpace for TinyOuter {
        fn name(&self) -> &str {
            "tiny-outer"
        }

        fn axes(&self) -> &[Axis] {
            &self.axes
        }

        fn materialize(&self, c: &Candidate) -> Result<Design> {
            crate::ensure!(self.in_bounds(c), "out of bounds");
            let n = self.axes[0].values.num(c.0[0] as usize) as usize;
            let hw = tiny_hw(4);
            let core0 = hw.points_of_kind("compute")[0];
            let mut graph = TaskGraph::new();
            let mut mapping = Mapping::new();
            for i in 0..n {
                let mut cost = ComputeCost::zero(OpClass::Elementwise);
                cost.vec_flops = 10_000.0 * (1 + i) as f64;
                let t = graph.add(format!("t{i}"), TaskKind::Compute(cost));
                mapping.map(t, core0);
            }
            let mut d = Design::new(Workload {
                hw,
                graph,
                mapping,
                name: "tiny".into(),
                notes: Vec::new(),
            });
            d.area_mm2 = Some(100.0 + n as f64);
            Ok(d)
        }
    }

    #[test]
    fn product_concatenates_axes_and_splits_candidates() {
        let param = ParamSpace::dmc("dmc", true)
            .axis("cfg", &[1.0, 2.0])
            .unwrap();
        let program = ProgramSpace::floating(
            "prog",
            MappingProgram::new(vec![Prim::MapNode {
                task: TaskSel::Heaviest,
                point: Param::hole("p", &[0, 5, 9]),
            }]),
        )
        .unwrap();
        let product =
            ProductSpace::new("joint", vec![Box::new(param), Box::new(program)]).unwrap();
        assert_eq!(product.axes().len(), 2);
        assert_eq!(product.axes()[0].name, "dmc.cfg");
        assert_eq!(product.axes()[0].kind, AxisKind::Arch);
        assert_eq!(product.axes()[1].name, "prog.p");
        assert_eq!(product.axes()[1].kind, AxisKind::Mapping);
        assert_eq!(product.size(), 6);
        let parts = product.split(&Candidate(vec![1, 2]));
        assert_eq!(parts[0].0, vec![1]);
        assert_eq!(parts[1].0, vec![2]);
        // materialize = param workload refined by the program: choosing a
        // different hole value moves the heaviest task, nothing else
        let d0 = product.materialize(&Candidate(vec![0, 0])).unwrap();
        let d1 = product.materialize(&Candidate(vec![0, 1])).unwrap();
        assert_eq!(d0.workload.graph.len(), d1.workload.graph.len());
        assert_ne!(d0.workload.mapping, d1.workload.mapping);
        // side figures from the materializing sub survive refinement
        assert!(d1.area_mm2.unwrap() > 0.0);
    }

    #[test]
    fn product_topology_key_composes_per_sub() {
        let param = ParamSpace::dmc("dmc", true)
            .axis("cfg", &[1.0, 2.0])
            .unwrap();
        let program = ProgramSpace::floating(
            "prog",
            MappingProgram::new(vec![Prim::MapNode {
                task: TaskSel::Heaviest,
                point: Param::hole("p", &[0, 5]),
            }]),
        )
        .unwrap();
        let product =
            ProductSpace::new("joint", vec![Box::new(param), Box::new(program)]).unwrap();
        // param digits key the hardware; mapping digits are shared out
        let k00 = product.topology_key(&Candidate(vec![0, 0])).unwrap();
        let k01 = product.topology_key(&Candidate(vec![0, 1])).unwrap();
        let k10 = product.topology_key(&Candidate(vec![1, 0])).unwrap();
        assert_eq!(k00, k01, "mapping digit must not change the key");
        assert_ne!(k00, k10, "hw digit must change the key");

        // a tiling program under a hole forfeits sharing
        let tiling = ProgramSpace::floating(
            "tile",
            MappingProgram::new(vec![Prim::GreedyRounds {
                rounds: Param::hole("r", &[0, 1]),
            }]),
        )
        .unwrap();
        let param = ParamSpace::dmc("dmc", true)
            .axis("cfg", &[1.0, 2.0])
            .unwrap();
        let product =
            ProductSpace::new("joint2", vec![Box::new(param), Box::new(tiling)]).unwrap();
        assert_eq!(product.topology_key(&Candidate(vec![0, 0])), None);

        // a product of key-less hardware-only spaces stays key-less
        let a = ParamSpace::dmc("a", true).axis("cfg", &[1.0, 2.0]).unwrap();
        let product = ProductSpace::new("solo", vec![Box::new(a) as BoxSpace]).unwrap();
        assert_eq!(product.topology_key(&Candidate(vec![0])), None);
    }

    #[test]
    fn product_bind_agrees_with_materialize() {
        let param = ParamSpace::dmc("dmc", true)
            .axis("cfg", &[1.0, 2.0])
            .unwrap();
        let program = ProgramSpace::floating(
            "prog",
            MappingProgram::new(vec![Prim::MapNode {
                task: TaskSel::Heaviest,
                point: Param::hole("p", &[0, 5, 9]),
            }]),
        )
        .unwrap();
        let product =
            ProductSpace::new("joint", vec![Box::new(param), Box::new(program)]).unwrap();
        for digits in [vec![0, 0], vec![0, 2], vec![1, 1]] {
            let c = Candidate(digits);
            let d = product.materialize(&c).unwrap();
            let b = product.bind(&c).unwrap();
            assert_eq!(d.workload.mapping, b.mapping, "candidate {c:?}");
            assert_eq!(d.area_mm2, b.area_mm2);
        }
    }

    #[test]
    fn packaging_json_rejects_zero_cpp() {
        let err = space_from_json(r#"{"type": "packaging", "quick": true, "cpp": [0, 2]}"#)
            .unwrap_err();
        assert!(format!("{err:#}").contains(">= 1"), "{err:#}");
    }

    #[test]
    fn nested_instantiates_inner_once_per_outer_candidate() {
        static CALLS: AtomicUsize = AtomicUsize::new(0);
        let factory: InnerFactory = Box::new(|_c, design: &Design| {
            CALLS.fetch_add(1, Ordering::SeqCst);
            let w = &design.workload;
            ProgramSpace::over(
                "inner",
                w.hw.clone(),
                w.graph.clone(),
                w.mapping.clone(),
                placement_program(1),
            )
            .map(|s| Box::new(s) as BoxSpace)
        });
        let nested = NestedSpace::new("nest", Box::new(TinyOuter::new()), factory).unwrap();
        assert_eq!(CALLS.load(Ordering::SeqCst), 1, "template instantiation");
        // axes: outer `tasks` + inner hole over 4 compute points
        assert_eq!(nested.axes().len(), 2);
        assert_eq!(nested.axes()[0].kind, AxisKind::Arch);
        assert_eq!(nested.axes()[1].kind, AxisKind::Mapping);
        assert_eq!(nested.size(), 2 * 4);
        assert_eq!(nested.outer_digits(), 1);
        // the template instantiation is reused for the initial outer point
        for inner in 0..4 {
            nested.materialize(&Candidate(vec![0, inner])).unwrap();
        }
        assert_eq!(CALLS.load(Ordering::SeqCst), 1);
        // a new outer candidate instantiates exactly once more
        for inner in 0..4 {
            let d = nested.materialize(&Candidate(vec![1, inner])).unwrap();
            // outer side figures propagate to nested candidates
            assert_eq!(d.area_mm2, Some(103.0));
        }
        assert_eq!(CALLS.load(Ordering::SeqCst), 2);
    }

    #[test]
    fn nested_topology_key_prefixes_outer_digits() {
        let nested = NestedSpace::with_program(
            "nest",
            Box::new(TinyOuter::new()),
            placement_program(1),
        )
        .unwrap();
        let k_a = nested.topology_key(&Candidate(vec![0, 0])).unwrap();
        let k_b = nested.topology_key(&Candidate(vec![0, 3])).unwrap();
        let k_c = nested.topology_key(&Candidate(vec![1, 0])).unwrap();
        assert_eq!(k_a, vec![0]);
        assert_eq!(k_a, k_b, "inner digits must not change the key");
        assert_ne!(k_a, k_c, "outer digits must change the key");
        // bind rebinds against the cached inner instantiation
        let b = nested.bind(&Candidate(vec![0, 2])).unwrap();
        let d = nested.materialize(&Candidate(vec![0, 2])).unwrap();
        assert_eq!(b.mapping, d.workload.mapping);
        assert_eq!(b.area_mm2, d.area_mm2);
    }

    #[test]
    fn nested_initial_concatenates() {
        let nested = NestedSpace::with_program(
            "nest",
            Box::new(TinyOuter::new()),
            placement_program(1),
        )
        .unwrap();
        assert_eq!(nested.initial().0, vec![0, 0]);
        assert!(nested.in_bounds(&nested.initial()));
    }

    #[test]
    fn three_tier_quick_has_all_three_tiers() {
        let space = three_tier("tt", true).unwrap();
        let kinds: Vec<AxisKind> = space.axes().iter().map(|a| a.kind).collect();
        assert!(kinds.contains(&AxisKind::Arch), "{kinds:?}");
        assert!(kinds.contains(&AxisKind::HwParam), "{kinds:?}");
        assert!(kinds.contains(&AxisKind::Mapping), "{kinds:?}");
        // outer = packaging, cpp, lmem_bw; inner = 2 placement holes
        assert_eq!(space.outer_digits(), 3);
        assert_eq!(space.axes().len(), 5);
        // joint candidates share setups per outer point
        let init = space.initial();
        assert_eq!(space.topology_key(&init).unwrap(), vec![0, 0, 0]);
        // manufacturing cost flows from the outer packaging design
        let d = space.materialize(&init).unwrap();
        assert!(d.cost_usd.unwrap() > 0.0);
    }

    #[test]
    fn json_nested_space_parses_and_materializes() {
        let text = r#"{
            "type": "nested",
            "name": "tt-json",
            "outer": {"type": "packaging", "quick": true, "lmem_bw": [76, 304]},
            "program": [
                {"op": "map_node", "task": "heaviest",
                 "point": {"hole": "p0", "points": "compute"}},
                {"op": "map_node", "task": "heaviest",
                 "point": {"hole": "p1", "points": "compute"}}
            ]
        }"#;
        let space = space_from_json(text).unwrap();
        assert_eq!(space.name(), "tt-json");
        // identical shape to the built-in three-tier quick space
        let preset = three_tier("tt", true).unwrap();
        assert_eq!(space.axes().len(), preset.axes().len());
        for (a, b) in space.axes().iter().zip(preset.axes()) {
            assert_eq!(a.len(), b.len(), "{} vs {}", a.name, b.name);
            assert_eq!(a.kind, b.kind);
        }
        let d = space.materialize(&space.initial()).unwrap();
        assert!(d.workload.graph.len() > 0);
    }

    #[test]
    fn json_product_space_parses() {
        let text = r#"{
            "type": "product",
            "name": "joint",
            "subs": [
                {"type": "param", "arch": "dmc", "quick": true,
                 "axes": {"cfg": [1, 2]}},
                {"type": "program", "name": "remap", "program": [
                    {"op": "map_node", "task": "heaviest",
                     "point": {"hole": "p", "choices": [0, 3]}}
                ]}
            ]
        }"#;
        let space = space_from_json(text).unwrap();
        assert_eq!(space.size(), 4);
        let d = space.materialize(&space.nth(3)).unwrap();
        assert!(d.workload.graph.len() > 0);
    }

    #[test]
    fn json_space_errors_are_descriptive() {
        // unknown type
        let err = space_from_json(r#"{"type": "warp"}"#).unwrap_err();
        assert!(format!("{err:#}").contains("warp"), "{err:#}");
        // top-level program rejected with guidance
        let err = space_from_json(r#"{"type": "program", "program": []}"#).unwrap_err();
        assert!(format!("{err:#}").contains("nested"), "{err:#}");
        // program as the *first* product sub rejected
        let err = space_from_json(
            r#"{"type": "product", "subs": [{"type": "program", "program": []}]}"#,
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("first sub"), "{err:#}");
        // nested without a program
        let err = space_from_json(
            r#"{"type": "nested", "outer": {"type": "packaging", "quick": true}}"#,
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("program"), "{err:#}");
        // no-type default remains the classic param schema
        assert!(space_from_json(r#"{"arch": "dmc", "axes": {"cfg": [1]}}"#).is_ok());
    }

    #[test]
    fn objectives_parse_from_json() {
        let doc = Json::parse(
            r#"{"objectives": ["makespan", "edp", "cost_usd", "makespan@area<=900"]}"#,
        )
        .unwrap();
        let objs = objectives_from_json(&doc).unwrap().unwrap();
        assert_eq!(objs.len(), 4);
        assert_eq!(objs[0].name(), "makespan");
        assert_eq!(objs[3].name(), "makespan@area<=900mm2");
        // absent key -> None (caller falls back to defaults)
        let doc = Json::parse("{}").unwrap();
        assert!(objectives_from_json(&doc).unwrap().is_none());
        // unknown objective
        let doc = Json::parse(r#"{"objectives": ["speed"]}"#).unwrap();
        assert!(objectives_from_json(&doc).is_err());
    }
}
