//! `mldse` — command-line interface to the MLDSE infrastructure.
//!
//! ```text
//! mldse info                                   artifact + registry status
//! mldse simulate --arch dmc|gsm [--config N] [--seq N] [--pjrt] [--json]
//! mldse decode --mode temporal|spatial [--pos N] [--layers N] [--cpp N]
//! mldse experiment <name>|all [--quick] [--csv]
//! mldse hardware --spec FILE                   build + describe a spec
//! ```
//!
//! (Hand-rolled argument parsing: the offline vendor set has no clap.)

use std::process::ExitCode;

use mldse::arch::{DmcParams, GsmParams, MpmcParams};
use mldse::coordinator::{Coordinator, EXPERIMENTS};
use mldse::cost::Packaging;
use mldse::sim::SimConfig;
use mldse::util::error::Result;
use mldse::util::json::{Json, JsonObj};
use mldse::workloads::{
    dmc_decode_temporal, dmc_prefill, gsm_prefill, mpmc_decode_spatial, LlmConfig,
};

struct Args {
    positional: Vec<String>,
    flags: std::collections::HashMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Args {
        let mut positional = Vec::new();
        let mut flags = std::collections::HashMap::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                let value = if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    i += 1;
                    argv[i].clone()
                } else {
                    "true".to_string()
                };
                flags.insert(name.to_string(), value);
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        Args { positional, flags }
    }

    fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    fn bool_flag(&self, name: &str) -> bool {
        self.flag(name) == Some("true")
    }

    fn num<T: std::str::FromStr>(&self, name: &str, default: T) -> T {
        self.flag(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }
}

fn main() -> ExitCode {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        print_usage();
        return ExitCode::FAILURE;
    }
    let cmd = argv[0].clone();
    let args = Args::parse(&argv[1..]);
    let result = match cmd.as_str() {
        "info" => cmd_info(),
        "simulate" => cmd_simulate(&args),
        "decode" => cmd_decode(&args),
        "experiment" => cmd_experiment(&args),
        "hardware" => cmd_hardware(&args),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => {
            eprintln!("unknown command '{other}'");
            print_usage();
            return ExitCode::FAILURE;
        }
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

fn print_usage() {
    eprintln!(
        "mldse — Multi-Level Design Space Explorer\n\
         \n\
         commands:\n\
           info                                  runtime + artifact status\n\
           simulate --arch dmc|gsm [--config 1-4] [--seq N] [--pjrt] [--json] [--trace out.json]\n\
           decode --mode temporal|spatial [--pos N] [--layers N] [--cpp N] [--packaging mcm|2.5d]\n\
           experiment <{}>|all [--quick] [--csv]\n\
           hardware --spec FILE.json\n",
        EXPERIMENTS.join("|")
    );
}

fn cmd_info() -> Result<()> {
    println!("mldse {}", env!("CARGO_PKG_VERSION"));
    let art = mldse::runtime::artifacts_dir();
    println!("artifacts dir: {}", art.display());
    let eval_art = art.join("evaluator_b128.hlo.txt");
    println!(
        "evaluator artifact: {}",
        if eval_art.exists() { "present" } else { "MISSING (run `make artifacts`)" }
    );
    if eval_art.exists() {
        match Coordinator::with_pjrt() {
            Ok(_) => println!("PJRT runtime: ok"),
            Err(e) => println!("PJRT runtime: FAILED ({e:#})"),
        }
    }
    println!("experiments: {}", EXPERIMENTS.join(", "));
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let arch = args.flag("arch").unwrap_or("dmc");
    let config = args.num("config", 2usize);
    let seq = args.num("seq", 2048u32);
    let cfg = LlmConfig::gpt3_6_7b();
    let workload = match arch {
        "dmc" => dmc_prefill(&cfg, seq, &DmcParams::table2(config)),
        "gsm" => gsm_prefill(&cfg, seq, &GsmParams::table2(config)),
        other => mldse::bail!("unknown arch '{other}'"),
    };
    let coord = if args.bool_flag("pjrt") {
        Coordinator::with_pjrt()?
    } else {
        Coordinator::standard()
    };
    let sim_cfg = SimConfig {
        iterations: args.num("iterations", 1u32),
        collect_timeline: args.flag("trace").is_some(),
        ..Default::default()
    };
    let r = if args.bool_flag("pjrt") {
        coord.simulate_pjrt(&workload, &sim_cfg)?
    } else {
        coord.simulate(&workload, &sim_cfg)?
    };
    if args.bool_flag("json") {
        let mut o = JsonObj::new();
        o.insert("workload", workload.name.as_str().into());
        o.insert("makespan_cycles", r.makespan.into());
        o.insert("tasks_completed", r.completed.into());
        o.insert("truncations", r.truncations.into());
        o.insert(
            "notes",
            Json::Arr(workload.notes.iter().map(|n| n.as_str().into()).collect()),
        );
        println!("{}", Json::Obj(o).to_pretty());
    } else {
        println!("workload: {}", workload.name);
        for n in &workload.notes {
            println!("  note: {n}");
        }
        println!("makespan: {:.0} cycles", r.makespan);
        println!("tasks: {} completed, {} unfinished", r.completed, r.unfinished);
        println!("contention truncations: {}", r.truncations);
        println!(
            "energy: {:.3} mJ (avg power {:.1} W @1GHz)",
            r.total_energy() * 1e-9,
            r.avg_power_w(1.0)
        );
        if let Some((h, m)) = coord.pjrt_stats() {
            println!("pjrt cache: {h} hits / {m} misses");
        }
    }
    if let Some(path) = args.flag("trace") {
        let doc = mldse::sim::chrome_trace(&r, &workload.hw, &workload.graph);
        std::fs::write(path, doc.to_pretty())?;
        eprintln!("wrote Chrome trace to {path} (open in chrome://tracing)");
    }
    Ok(())
}

fn cmd_decode(args: &Args) -> Result<()> {
    let mode = args.flag("mode").unwrap_or("spatial");
    let pos = args.num("pos", 2048u32);
    let layers = args.num("layers", 8u32);
    let cfg = LlmConfig::gpt3_6_7b();
    let coord = Coordinator::standard();
    let w = match mode {
        "temporal" => dmc_decode_temporal(&cfg, pos, layers, &DmcParams::default()),
        "spatial" => {
            let cpp = args.num("cpp", 2usize);
            let pkg = match args.flag("packaging").unwrap_or("mcm") {
                "2.5d" | "interposer" => Packaging::Interposer2_5D,
                _ => Packaging::Mcm,
            };
            mpmc_decode_spatial(&cfg, pos, layers, &MpmcParams::paper(cpp, pkg))
        }
        other => mldse::bail!("unknown decode mode '{other}'"),
    };
    let r = coord.simulate(&w, &SimConfig::default())?;
    println!("workload: {}", w.name);
    for n in &w.notes {
        println!("  note: {n}");
    }
    println!("decode makespan: {:.0} cycles", r.makespan);
    Ok(())
}

fn cmd_experiment(args: &Args) -> Result<()> {
    let name = args
        .positional
        .first()
        .map(|s| s.as_str())
        .unwrap_or("all");
    let quick = args.bool_flag("quick");
    let coord = Coordinator::standard();
    let names: Vec<&str> = if name == "all" {
        EXPERIMENTS.to_vec()
    } else {
        vec![name]
    };
    for n in names {
        let tables = coord.run_experiment(n, quick)?;
        for t in tables {
            if args.bool_flag("csv") {
                println!("# {n}");
                print!("{}", t.to_csv());
            } else {
                println!("{}", t.render());
            }
        }
    }
    Ok(())
}

fn cmd_hardware(args: &Args) -> Result<()> {
    let path = args
        .flag("spec")
        .ok_or_else(|| mldse::format_err!("--spec FILE required"))?;
    let text = std::fs::read_to_string(path)?;
    let matrix = mldse::hwir::parse_spec(&text)?;
    let hw = mldse::hwir::Hardware::build(matrix);
    println!("points: {}", hw.num_points());
    for kind in ["compute", "memory", "dram", "comm"] {
        println!("  {kind}: {}", hw.points_of_kind(kind).len());
    }
    println!("depth: {} levels", hw.root.depth());
    println!("sync groups: {}", hw.sync_groups().len());
    Ok(())
}
