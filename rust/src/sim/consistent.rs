//! The paper's **hardware-consistent dynamic task scheduler** (Algorithm 1,
//! §6.2) — speculative per-point zone scheduling with a contention-staged
//! buffer (CSB).
//!
//! Unlike [`super::engine`], which sidesteps inconsistency by processing
//! events in global time order, this scheduler issues *contention zones*
//! (all activated tasks on one point) eagerly, stages their evaluations in
//! the CSB, and repairs speculation through the paper's two rules:
//!
//! * **commit** — a staged evaluation `v` becomes final when every not-yet-
//!   activated task mapped to the same point has an earliest-possible start
//!   (a dependency-propagated lower bound) no earlier than `End(v)`
//!   (`can_be_committed`). Only committed completions fire ticks.
//! * **rollback** — when a task activates on a point at a time earlier than
//!   a staged (or partially progressed) evaluation extends, every item on
//!   that point is truncated back to the arrival time and re-enters the
//!   schedule queue for co-evaluation with the newcomer
//!   (`should_be_rollback`; the paper's `v3[2] -> v3[2][1] + v3[2][2]`
//!   split).
//!
//! Task truncation is explicit: each transfer keeps a piecewise-constant
//! rate profile, so the prefix before a rollback point survives and only
//! the remainder is re-evaluated. The scheduler satisfies the paper's
//! Constraints 1–3 and — by the equivalence tests at the bottom — produces
//! the same timings as the exact engine while evaluating in a different
//! (per-point speculative) order.
//!
//! Scope: single iteration, static task graphs. Storage occupancy
//! accounting and dynamic executors live in the main engine.

use std::collections::HashMap;

use crate::eval::Registry;
use crate::hwir::{Hardware, PointId};
use crate::mapping::Mapping;
use crate::taskgraph::{TaskGraph, TaskId, TaskKind};

use super::engine::{completion_eps, SimError, SimResult, Time};
use super::links::RouteTable;

/// A piecewise-constant progress profile of a transfer.
#[derive(Debug, Clone, Default)]
struct Profile {
    /// (from, to, rate) segments, contiguous, in time order.
    segments: Vec<(Time, Time, f64)>,
}

impl Profile {
    fn work_done(&self) -> f64 {
        self.segments.iter().map(|(a, b, r)| (b - a) * r).sum()
    }

    /// Drop all progress after `t`.
    fn truncate_at(&mut self, t: Time) {
        self.segments.retain(|(a, _, _)| *a < t);
        if let Some(last) = self.segments.last_mut() {
            if last.1 > t {
                last.1 = t;
            }
        }
    }

    fn push(&mut self, from: Time, to: Time, rate: f64) {
        if to > from && rate > 0.0 {
            self.segments.push((from, to, rate));
        }
    }
}

/// An activated-but-uncommitted piece of work.
#[derive(Debug, Clone)]
struct Item {
    task: TaskId,
    point: PointId,
    /// Activation time (exact: all predecessors committed).
    ready: Time,
    shared_total: f64,
    fixed: f64,
    /// Dense per-point link indices from the shared [`RouteTable`];
    /// empty = shares the whole resource.
    links: Vec<u32>,
    exclusive: bool,
    profile: Profile,
    /// Staged completion time (`None` while still pending in S).
    staged_end: Option<Time>,
}

impl Item {
    fn remaining(&self) -> f64 {
        (self.shared_total - self.profile.work_done()).max(0.0)
    }

    /// Earliest time this item can make further progress.
    fn resume_at(&self) -> Time {
        self.profile
            .segments
            .last()
            .map(|(_, b, _)| *b)
            .unwrap_or(self.ready)
    }
}

/// Run Algorithm 1. Semantics match [`super::engine::simulate`] with
/// `iterations = 1`.
pub fn simulate_consistent(
    hw: &Hardware,
    graph: &TaskGraph,
    mapping: &Mapping,
    evals: &Registry,
) -> Result<SimResult, SimError> {
    // Shared link-set machinery with the exact engine: intern every routed
    // flow's link set once, remapped to dense per-point indices.
    let routes = RouteTable::from_mapping(hw, graph, mapping);
    Alg1 {
        hw,
        graph,
        mapping,
        evals,
        routes,
        items: Vec::new(),
        committed: HashMap::new(),
        deps_left: HashMap::new(),
        ready_time: HashMap::new(),
        sync_ready: HashMap::new(),
        result: SimResult::default(),
        min_demand_memo: HashMap::new(),
    }
    .run()
}

struct Alg1<'a> {
    hw: &'a Hardware,
    graph: &'a TaskGraph,
    mapping: &'a Mapping,
    evals: &'a Registry,
    routes: RouteTable,
    /// S ∪ CSB: pending items (staged_end == None) and staged items.
    items: Vec<Item>,
    /// Committed completion times.
    committed: HashMap<TaskId, Time>,
    deps_left: HashMap<TaskId, usize>,
    ready_time: HashMap<TaskId, Time>,
    /// sync_id -> (ready members, max ready)
    sync_ready: HashMap<u32, (usize, Time)>,
    result: SimResult,
    min_demand_memo: HashMap<TaskId, f64>,
}

impl<'a> Alg1<'a> {
    fn run(mut self) -> Result<SimResult, SimError> {
        // Validate mapping (reuse engine's checks indirectly).
        for task in self.graph.iter().filter(|t| t.enabled) {
            if self.mapping.point_of(task.id).is_none() {
                return Err(SimError(format!("task {} unmapped", task.name)));
            }
        }
        // Activate sources.
        let sources: Vec<TaskId> = self
            .graph
            .iter()
            .filter(|t| {
                t.enabled
                    && self
                        .graph
                        .predecessors(t.id)
                        .iter()
                        .all(|p| !self.graph.task(*p).enabled)
            })
            .map(|t| t.id)
            .collect();
        for s in sources {
            self.activate(s, 0.0);
        }

        let mut guard = 0u64;
        loop {
            guard += 1;
            if guard > 50_000_000 {
                return Err(SimError("algorithm-1 scheduler did not converge".into()));
            }
            // Commit scan (repeats until fixpoint because commits activate
            // successors, which may enable further commits or rollbacks).
            if self.commit_pass() {
                continue;
            }
            // Issue the zone with the earliest possible start.
            if self.issue_pass() {
                continue;
            }
            // Fallback progress: commit the globally-earliest staged end.
            if self.commit_min_end() {
                continue;
            }
            break;
        }

        for t in self.graph.iter().filter(|t| t.enabled) {
            if !self.committed.contains_key(&t.id) {
                self.result.unfinished += 1;
            }
        }
        Ok(self.result)
    }

    // ------------------------------------------------------------------
    // Activation & ticks
    // ------------------------------------------------------------------

    fn activate(&mut self, task: TaskId, at: Time) {
        let t = self.graph.task(task);
        let point = self.mapping.point_of(task).unwrap();
        match &t.kind {
            // Zero-demand tasks: exact completion at activation.
            TaskKind::Storage { .. } => {
                self.commit(task, at, at);
                return;
            }
            TaskKind::Sync { sync_id } => {
                let members = self
                    .graph
                    .iter()
                    .filter(|x| {
                        x.enabled
                            && matches!(&x.kind, TaskKind::Sync { sync_id: s } if s == sync_id)
                    })
                    .count();
                let entry = self.sync_ready.entry(*sync_id).or_insert((0, 0.0));
                entry.0 += 1;
                entry.1 = entry.1.max(at);
                if entry.0 == members {
                    let when = entry.1;
                    let ids: Vec<TaskId> = self
                        .graph
                        .iter()
                        .filter(|x| {
                            x.enabled
                                && matches!(&x.kind, TaskKind::Sync { sync_id: s } if s == sync_id)
                        })
                        .map(|x| x.id)
                        .collect();
                    for id in ids {
                        self.commit(id, when, when);
                    }
                }
                return;
            }
            _ => {}
        }
        let demand = self.evals.demand(t, self.hw.entry(point));
        let exclusive = self.hw.point(point).kind.is_compute();
        let links = self.routes.links_of(task).to_vec();
        // Rollback rule: the newcomer invalidates any evaluation on this
        // point that extends beyond its arrival.
        self.rollback_point(point, at);
        self.items.push(Item {
            task,
            point,
            ready: at,
            // exclusive tasks are atomic: all demand in `shared_total`
            shared_total: if exclusive {
                demand.total()
            } else {
                demand.shared
            },
            fixed: if exclusive { 0.0 } else { demand.fixed },
            links,
            exclusive,
            profile: Profile::default(),
            staged_end: None,
        });
    }

    fn commit(&mut self, task: TaskId, start: Time, end: Time) {
        self.committed.insert(task, end);
        self.result.completed += 1;
        self.result.makespan = self.result.makespan.max(end);
        self.result.timings.insert(task, (start, end));
        // fire ticks
        for &s in self.graph.successors(task) {
            if !self.graph.task(s).enabled {
                continue;
            }
            let left = self.deps_left.entry(s).or_insert_with(|| {
                self.graph
                    .predecessors(s)
                    .iter()
                    .filter(|p| self.graph.task(**p).enabled)
                    .count()
            });
            *left -= 1;
            let rt = self.ready_time.entry(s).or_insert(0.0);
            *rt = rt.max(end);
            if *left == 0 {
                let at = *rt;
                self.activate(s, at);
            }
        }
    }

    // ------------------------------------------------------------------
    // Rollback (should_be_rollback + truncation)
    // ------------------------------------------------------------------

    /// Truncate every item on `point` back to time `t`; staged items whose
    /// end exceeds `t` return to the schedule queue.
    fn rollback_point(&mut self, point: PointId, t: Time) {
        for item in &mut self.items {
            if item.point != point {
                continue;
            }
            if item.exclusive {
                if let Some(end) = item.staged_end {
                    // retract only if the newcomer should have gone first
                    if t < end {
                        item.staged_end = None;
                        item.profile = Profile::default();
                        self.result.rollbacks += 1;
                    }
                }
            } else if item.resume_at() > t || item.staged_end.map(|e| e - item.fixed > t).unwrap_or(false) {
                if item.staged_end.is_some() {
                    self.result.rollbacks += 1;
                }
                item.profile.truncate_at(t);
                item.staged_end = None;
                self.result.truncations += 1;
            }
        }
    }

    // ------------------------------------------------------------------
    // Commit (can_be_committed)
    // ------------------------------------------------------------------

    /// Dependency-propagated lower bound on a task's activation time.
    fn lb_start(&mut self, task: TaskId) -> Time {
        if let Some(end) = self.committed.get(&task) {
            return *end; // already done; cannot threaten anyone later
        }
        if let Some(rt) = self.ready_time.get(&task) {
            if self.deps_left.get(&task) == Some(&0) {
                return *rt;
            }
        }
        // max over preds of lower-bound end
        let preds: Vec<TaskId> = self
            .graph
            .predecessors(task)
            .iter()
            .filter(|p| self.graph.task(**p).enabled)
            .copied()
            .collect();
        let mut lb: Time = 0.0;
        for p in preds {
            lb = lb.max(self.lb_end(p));
        }
        lb
    }

    fn lb_end(&mut self, task: TaskId) -> Time {
        if let Some(end) = self.committed.get(&task) {
            return *end;
        }
        if let Some(item) = self.items.iter().find(|i| i.task == task) {
            if let Some(end) = item.staged_end {
                return end; // rollbacks only push ends later
            }
        }
        let min_d = match self.min_demand_memo.get(&task) {
            Some(d) => *d,
            None => {
                let t = self.graph.task(task);
                let d = match self.mapping.point_of(task) {
                    Some(p) => self.evals.demand(t, self.hw.entry(p)).total(),
                    None => 0.0,
                };
                self.min_demand_memo.insert(task, d);
                d
            }
        };
        self.lb_start(task) + min_d
    }

    /// Commit every staged item that is provably safe. Returns true if
    /// anything was committed.
    fn commit_pass(&mut self) -> bool {
        let mut progress = false;
        loop {
            // pick the safest candidate: smallest staged end first
            let staged: Vec<(TaskId, PointId, Time)> = self
                .items
                .iter()
                .filter_map(|i| i.staged_end.map(|e| (i.task, i.point, e)))
                .collect();
            let mut committed_one = false;
            for (task, point, end) in staged {
                if self.can_commit(task, point, end) {
                    let idx = self.items.iter().position(|i| i.task == task).unwrap();
                    let item = self.items.remove(idx);
                    let start = item.ready;
                    *self.result.point_busy.entry_or(point, 0.0) += item.shared_total;
                    self.commit(task, start, end);
                    committed_one = true;
                    progress = true;
                    break; // items changed; re-scan
                }
            }
            if !committed_one {
                return progress;
            }
        }
    }

    fn can_commit(&mut self, task: TaskId, point: PointId, end: Time) -> bool {
        // pending items on the same point are already co-evaluated up to
        // their profiles; only *unactivated* tasks threaten `task`.
        let candidates: Vec<TaskId> = self
            .mapping
            .tasks_on(point)
            .into_iter()
            .filter(|t| {
                *t != task
                    && self.graph.task(*t).enabled
                    && !self.committed.contains_key(t)
                    && !self.items.iter().any(|i| i.task == *t)
            })
            .collect();
        for u in candidates {
            if self.lb_start(u) < end {
                return false;
            }
        }
        true
    }

    /// Progress fallback: nothing is pending, so the globally smallest
    /// staged end can never be contradicted.
    fn commit_min_end(&mut self) -> bool {
        if self.items.iter().any(|i| i.staged_end.is_none()) {
            return false;
        }
        let Some((task, point, end)) = self
            .items
            .iter()
            .filter_map(|i| i.staged_end.map(|e| (i.task, i.point, e)))
            .min_by(|a, b| a.2.total_cmp(&b.2))
        else {
            return false;
        };
        let idx = self.items.iter().position(|i| i.task == task).unwrap();
        let item = self.items.remove(idx);
        *self.result.point_busy.entry_or(point, 0.0) += item.shared_total;
        self.commit(task, item.ready, end);
        true
    }

    // ------------------------------------------------------------------
    // Issue (zones + truncation)
    // ------------------------------------------------------------------

    /// Issue the zone with the earliest possible start. Returns true if a
    /// zone was evaluated.
    fn issue_pass(&mut self) -> bool {
        // candidate points with pending items
        let mut best: Option<(Time, PointId)> = None;
        for item in self.items.iter().filter(|i| i.staged_end.is_none()) {
            let t = if item.exclusive {
                let timer = self.excl_timer(item.point);
                item.resume_at().max(timer)
            } else {
                item.resume_at()
            };
            if best.map(|(bt, bp)| (t, item.point.0) < (bt, bp.0)).unwrap_or(true) {
                best = Some((t, item.point));
            }
        }
        let Some((_, point)) = best else {
            return false;
        };
        if self.hw.point(point).kind.is_compute() {
            self.issue_exclusive(point)
        } else {
            self.issue_shared_zone(point)
        }
    }

    /// Timer of an exclusive point = max end over committed/staged tasks.
    fn excl_timer(&self, point: PointId) -> Time {
        let mut t: Time = 0.0;
        for (task, end) in &self.committed {
            if self.mapping.point_of(*task) == Some(point) {
                t = t.max(*end);
            }
        }
        for item in &self.items {
            if item.point == point {
                if let Some(end) = item.staged_end {
                    t = t.max(end);
                }
            }
        }
        t
    }

    fn issue_exclusive(&mut self, point: PointId) -> bool {
        // earliest-ready pending task (ties by id), run atomically
        let Some(idx) = self
            .items
            .iter()
            .enumerate()
            .filter(|(_, i)| i.point == point && i.staged_end.is_none())
            .min_by(|(_, a), (_, b)| {
                a.ready
                    .total_cmp(&b.ready)
                    .then(a.task.cmp(&b.task))
            })
            .map(|(i, _)| i)
        else {
            return false;
        };
        let timer = self.excl_timer(point);
        let item = &mut self.items[idx];
        let start = item.ready.max(timer);
        let end = start + item.shared_total;
        item.profile = Profile {
            segments: vec![(start, end, 1.0)],
        };
        item.staged_end = Some(end);
        true
    }

    /// Fluid co-evaluation of all pending items on a shared point, stopped
    /// at the first completion (the paper's bind-and-truncate step).
    fn issue_shared_zone(&mut self, point: PointId) -> bool {
        let member_idx: Vec<usize> = self
            .items
            .iter()
            .enumerate()
            .filter(|(_, i)| i.point == point && i.staged_end.is_none())
            .map(|(i, _)| i)
            .collect();
        if member_idx.is_empty() {
            return false;
        }
        // Piecewise sim from the earliest resume point.
        let mut t: Time = member_idx
            .iter()
            .map(|&i| self.items[i].resume_at())
            .fold(f64::INFINITY, f64::min);
        let mut remaining: HashMap<usize, f64> =
            member_idx.iter().map(|&i| (i, self.items[i].remaining())).collect();

        // Completion tolerance scaled to each item's size and the current
        // zone time (see `engine::completion_eps`): with an absolute
        // epsilon a large transfer's — or a late small transfer's — float
        // residue never drops below it while the retry step rounds below
        // the time resolution, spinning this loop forever (the zone loop
        // has no event cap).
        let done_eps = |item: &Item, at: Time| completion_eps(item.shared_total, at);

        loop {
            // active members at time t
            let active: Vec<usize> = member_idx
                .iter()
                .copied()
                .filter(|&i| {
                    self.items[i].resume_at() <= t + 1e-12
                        && remaining[&i] > done_eps(&self.items[i], t)
                })
                .collect();
            // worked-off member completes instantly
            if let Some(&done) = member_idx.iter().find(|&&i| {
                remaining[&i] <= done_eps(&self.items[i], t) && self.items[i].staged_end.is_none()
            }) {
                let item = &mut self.items[done];
                let end_transfer = item.resume_at().max(item.ready);
                item.staged_end = Some(end_transfer + item.fixed);
                return true;
            }
            if active.is_empty() {
                // jump to the next entry
                let next = member_idx
                    .iter()
                    .map(|&i| self.items[i].resume_at())
                    .filter(|&r| r > t)
                    .fold(f64::INFINITY, f64::min);
                if !next.is_finite() {
                    return false;
                }
                t = next;
                continue;
            }
            // rates among active members (same congestion rule as engine)
            let rates: Vec<f64> = active
                .iter()
                .map(|&i| {
                    let fi = &self.items[i];
                    let congestion = if fi.links.is_empty() {
                        active.len() as f64
                    } else {
                        let mut worst = 1usize;
                        for l in &fi.links {
                            let c = active
                                .iter()
                                .filter(|&&j| {
                                    let fj = &self.items[j];
                                    fj.links.is_empty() || fj.links.contains(l)
                                })
                                .count();
                            worst = worst.max(c);
                        }
                        worst as f64
                    };
                    1.0 / congestion.max(1.0)
                })
                .collect();
            // next event: first completion among active or next entry
            let mut dt = f64::INFINITY;
            for (&i, &r) in active.iter().zip(&rates) {
                dt = dt.min(remaining[&i] / r);
            }
            let next_entry = member_idx
                .iter()
                .map(|&i| self.items[i].resume_at())
                .filter(|&r| r > t)
                .fold(f64::INFINITY, f64::min);
            let t_next = (t + dt).min(next_entry);
            // advance profiles
            for (&i, &r) in active.iter().zip(&rates) {
                self.items[i].profile.push(t, t_next, r);
                *remaining.get_mut(&i).unwrap() -= (t_next - t) * r;
            }
            // completion?
            if let Some(&done) = active
                .iter()
                .find(|&&i| remaining[&i] <= done_eps(&self.items[i], t_next))
            {
                let item = &mut self.items[done];
                item.staged_end = Some(t_next + item.fixed);
                self.result.truncations += active.len() as u64 - 1;
                return true;
            }
            t = t_next;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::Registry;
    use crate::hwir::{
        mlc, CommAttrs, ComputeAttrs, Coord, Element, MemoryAttrs, SpaceMatrix, SpacePoint,
        Topology,
    };
    use crate::sim::engine::{simulate, SimConfig};
    use crate::taskgraph::{ComputeCost, OpClass};

    fn tiny_hw() -> Hardware {
        let mut m = SpaceMatrix::new("chip", vec![2]);
        for i in 0..2 {
            m.set(
                Coord::new(vec![i]),
                Element::Point(SpacePoint::compute(
                    "core",
                    ComputeAttrs::new((4, 4), 8).with_lmem(MemoryAttrs::new(1 << 20, 64.0, 0)),
                )),
            );
        }
        m.add_comm(SpacePoint::comm(
            "bus",
            CommAttrs::new(Topology::Bus, 1.0, 0),
        ));
        Hardware::build(m)
    }

    fn compute_task(cycles: f64) -> TaskKind {
        let mut c = ComputeCost::zero(OpClass::Elementwise);
        c.vec_flops = cycles * 16.0;
        TaskKind::Compute(c)
    }

    fn comm_task(bytes: u64) -> TaskKind {
        TaskKind::Comm { bytes, hops: 0, route: None }
    }

    /// The Fig. 6 walkthrough must produce the hardware-consistent numbers
    /// (identical to the exact engine) and exercise truncation + rollback.
    #[test]
    fn fig6_matches_engine_with_rollbacks() {
        let hw = tiny_hw();
        let mut g = TaskGraph::new();
        let e = g.add("E", compute_task(100.0));
        let a = g.add("A", comm_task(50));
        let f = g.add("F", comm_task(200));
        let b = g.add("B", compute_task(100.0));
        let c = g.add("C", comm_task(80));
        g.connect(e, a);
        g.connect(e, f);
        g.connect(a, b);
        g.connect(b, c);
        let core = hw.cell(&mlc(&[&[0]])).unwrap();
        let bus = hw.points_of_kind("comm")[0];
        let mut m = Mapping::new();
        m.map(e, core);
        m.map(b, core);
        for t in [a, f, c] {
            m.map(t, bus);
        }
        let r = simulate_consistent(&hw, &g, &m, &Registry::standard()).unwrap();
        assert_eq!(r.timings[&a].1, 200.0);
        assert_eq!(r.timings[&f].1, 400.0);
        assert_eq!(r.timings[&c].1, 430.0);
        assert!(r.truncations > 0, "zone truncation must occur");
        let exact = simulate(&hw, &g, &m, &Registry::standard(), &SimConfig::default()).unwrap();
        assert_eq!(r.makespan, exact.makespan);
    }

    /// Speculative issue must roll back: a long transfer is staged before a
    /// competing transfer's predecessor chain completes.
    #[test]
    fn speculation_rolls_back() {
        let hw = tiny_hw();
        let mut g = TaskGraph::new();
        // F starts immediately on the bus; chain e1->e2 later releases C.
        let f = g.add("F", comm_task(500));
        let e1 = g.add("e1", compute_task(50.0));
        let e2 = g.add("e2", compute_task(50.0));
        let c = g.add("C", comm_task(100));
        g.connect(e1, e2);
        g.connect(e2, c);
        let core = hw.cell(&mlc(&[&[0]])).unwrap();
        let bus = hw.points_of_kind("comm")[0];
        let mut m = Mapping::new();
        m.map(e1, core);
        m.map(e2, core);
        m.map(f, bus);
        m.map(c, bus);
        let r = simulate_consistent(&hw, &g, &m, &Registry::standard()).unwrap();
        let exact = simulate(&hw, &g, &m, &Registry::standard(), &SimConfig::default()).unwrap();
        // F: alone 0..100 (100 work), shares 100..300 with C (C: 100 work
        // done at 300), F remaining 300 alone -> 600.
        assert_eq!(r.timings[&c].1, 300.0);
        assert_eq!(r.timings[&f].1, 600.0);
        assert_eq!(r.makespan, exact.makespan);
    }

    #[test]
    fn exclusive_rollback_reorders_fifo() {
        let hw = tiny_hw();
        let mut g = TaskGraph::new();
        // u's chain makes it ready at 20 on core1; v ready at 30 on core1.
        // If v (on another source path) were staged first, u's arrival must
        // retract it.
        let a = g.add("a", compute_task(30.0)); // core0, done 30
        let v = g.add("v", compute_task(10.0)); // core1 after a
        let b = g.add("b", compute_task(20.0)); // core0 path, done 20
        let u = g.add("u", compute_task(100.0)); // core1 after b
        g.connect(a, v);
        g.connect(b, u);
        let core0 = hw.cell(&mlc(&[&[0]])).unwrap();
        let core1 = hw.cell(&mlc(&[&[1]])).unwrap();
        let mut m = Mapping::new();
        m.map(a, core0);
        m.map(b, core0);
        m.map(v, core1);
        m.map(u, core1);
        let r = simulate_consistent(&hw, &g, &m, &Registry::standard()).unwrap();
        let exact = simulate(&hw, &g, &m, &Registry::standard(), &SimConfig::default()).unwrap();
        assert_eq!(r.timings[&u], exact.timings[&u]);
        assert_eq!(r.timings[&v], exact.timings[&v]);
        assert_eq!(r.makespan, exact.makespan);
    }

    /// Randomized equivalence: Algorithm 1 and the exact engine agree on
    /// every task's completion time.
    #[test]
    fn prop_equivalent_to_engine() {
        use crate::util::propcheck::{check, Gen};
        check("algorithm-1 == exact engine", 40, |gen: &mut Gen| {
            let hw = tiny_hw();
            let core0 = hw.cell(&mlc(&[&[0]])).unwrap();
            let core1 = hw.cell(&mlc(&[&[1]])).unwrap();
            let bus = hw.points_of_kind("comm")[0];
            let n = gen.usize(2..=14);
            let mut g = TaskGraph::new();
            let mut m = Mapping::new();
            let mut ids = Vec::new();
            for i in 0..n {
                let (kind, point) = match gen.usize(0..=2) {
                    0 => (compute_task(gen.usize(1..=60) as f64), core0),
                    1 => (compute_task(gen.usize(1..=60) as f64), core1),
                    _ => (comm_task(gen.usize(1..=120) as u64), bus),
                };
                let id = g.add(format!("t{i}"), kind);
                m.map(id, point);
                ids.push(id);
            }
            for i in 0..n {
                for j in i + 1..n {
                    if gen.bool() && gen.bool() {
                        g.connect(ids[i], ids[j]);
                    }
                }
            }
            let alg1 = simulate_consistent(&hw, &g, &m, &Registry::standard())
                .map_err(|e| e.to_string())?;
            let exact = simulate(&hw, &g, &m, &Registry::standard(), &SimConfig::default())
                .map_err(|e| e.to_string())?;
            if (alg1.makespan - exact.makespan).abs() > 1e-6 {
                return Err(format!(
                    "makespan {} vs {}",
                    alg1.makespan, exact.makespan
                ));
            }
            for id in &ids {
                let a = alg1.timings[id].1;
                let b = exact.timings[id].1;
                if (a - b).abs() > 1e-6 {
                    return Err(format!("task {id}: {a} vs {b}"));
                }
            }
            Ok(())
        });
    }
}
