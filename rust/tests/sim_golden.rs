//! Golden equivalence: the incremental contention tracker must produce
//! **bit-identical** `SimResult`s (makespan, timings, busy/energy maps,
//! truncation counts, timeline) to the full per-event recompute across the
//! paper's workload families — the fig6 contention scenario, fig8-style
//! kernel/decode graphs, the fig9 DMC/GSM prefill workloads, and a
//! synthetic contended-NoC storm with mixed routed/universal flows.

use mldse::arch::{DmcParams, GsmParams};
use mldse::eval::Registry;
use mldse::mapping::Mapping;
use mldse::sim::{simulate, SimConfig, SimResult};
use mldse::taskgraph::{ComputeCost, OpClass, TaskGraph, TaskKind};
use mldse::workloads::{
    contended_noc, dmc_decode_temporal, dmc_prefill, gsm_prefill, LlmConfig, Workload,
};

fn small_llm() -> LlmConfig {
    LlmConfig {
        hidden: 256,
        heads: 4,
        ffn: 1024,
        layers: 2,
        elem_bytes: 2,
    }
}

fn small_dmc() -> DmcParams {
    let mut p = DmcParams::table2(2).unwrap();
    p.grid = (4, 4);
    p
}

/// Run both contention paths and assert full structural equality.
fn assert_bit_identical(w: &Workload, iterations: u32) -> (SimResult, SimResult) {
    let evals = Registry::standard();
    let base = SimConfig {
        iterations,
        collect_timeline: true,
        ..Default::default()
    };
    let incr = simulate(&w.hw, &w.graph, &w.mapping, &evals, &base)
        .unwrap_or_else(|e| panic!("incremental sim of {} failed: {e}", w.name));
    let full_cfg = SimConfig {
        incremental: false,
        ..base
    };
    let full = simulate(&w.hw, &w.graph, &w.mapping, &evals, &full_cfg)
        .unwrap_or_else(|e| panic!("full-recompute sim of {} failed: {e}", w.name));
    assert_eq!(
        incr, full,
        "incremental vs full recompute diverged for {}",
        w.name
    );
    (incr, full)
}

#[test]
fn golden_fig6_contention_scenario() {
    // The paper's Fig. 6 walkthrough: two transfers share a bus, a third
    // arrives mid-flight and truncates the survivor.
    let hw = small_dmc().build();
    let cores = hw.points_of_kind("compute");
    let noc = hw.points_named("noc")[0];
    let mut g = TaskGraph::new();
    let mut m = Mapping::new();
    let compute = |g: &mut TaskGraph, m: &mut Mapping, name: &str, cyc: f64, core: usize| {
        let mut c = ComputeCost::zero(OpClass::Elementwise);
        c.vec_flops = cyc * 2.0 * small_dmc().vector_lanes as f64;
        let t = g.add(name, TaskKind::Compute(c));
        m.map(t, cores[core]);
        t
    };
    let comm = |g: &mut TaskGraph, m: &mut Mapping, name: &str, bytes: u64| {
        let t = g.add(name, TaskKind::Comm { bytes, hops: 0, route: None });
        m.map(t, noc);
        t
    };
    let e = compute(&mut g, &mut m, "E", 100.0, 0);
    let a = comm(&mut g, &mut m, "A", 50);
    let f = comm(&mut g, &mut m, "F", 200);
    let b = compute(&mut g, &mut m, "B", 100.0, 1);
    let c = comm(&mut g, &mut m, "C", 80);
    g.connect(e, a);
    g.connect(e, f);
    g.connect(a, b);
    g.connect(b, c);
    let w = Workload {
        hw,
        graph: g,
        mapping: m,
        name: "fig6-golden".into(),
        notes: Vec::new(),
    };
    let (incr, _) = assert_bit_identical(&w, 1);
    assert!(incr.truncations > 0, "fig6 must exercise truncation");
}

#[test]
fn golden_contended_noc_storm() {
    // Mixed routed + universal flows hammering one mesh NoC: the exact
    // scenario the incremental occupancy tracker optimizes, built by the
    // same generator `benches/sim_speed.rs` measures — what the bench
    // times is what the golden test proves bit-identical.
    let w = contended_noc(48, (4, 4), 0xD5E);
    let (incr, _) = assert_bit_identical(&w, 2);
    assert!(incr.truncations > 0, "storm must exercise contention");
    assert_eq!(incr.unfinished, 0);
}

#[test]
fn golden_fig9_dmc_prefill() {
    let w = dmc_prefill(&small_llm(), 128, &small_dmc());
    assert_bit_identical(&w, 1);
    // multi-iteration streaming must agree too
    assert_bit_identical(&w, 3);
}

#[test]
fn golden_fig9_gsm_prefill() {
    let mut p = GsmParams::table2(2).unwrap();
    p.sms = 16;
    let w = gsm_prefill(&small_llm(), 128, &p);
    assert_bit_identical(&w, 1);
}

#[test]
fn golden_fig8_decode_graph() {
    let w = dmc_decode_temporal(&small_llm(), 128, 2, &small_dmc());
    assert_bit_identical(&w, 1);
}
