//! Static lints over mapping programs (§5 primitive sequences).
//!
//! A program is checked by **replaying it once** against a base
//! (hardware, task graph, base mapping) at the all-zeros hole binding —
//! replay runs graph-transformation primitives only, no simulation — and
//! then linting the transformed graph + mapping:
//!
//! * deadlock cycles through the sync-edge closure (barrier sync tasks
//!   sharing a `sync_id` complete together, so they are contracted into
//!   one node before cycle detection),
//! * enabled tasks left unmapped, kind-incompatible placements,
//!   disabled tasks whose consumers still run,
//! * lower-bound capacity/bandwidth feasibility: per-task footprint vs.
//!   lmem capacity, per-point storage residency vs. memory capacity, and
//!   total link flow vs. the compute lower bound.
//!
//! Two input shapes are accepted: a bare JSON array (the `"program"`
//! field of nested spaces) replayed on a demo base — a 2×2 DMC grid with
//! eight elementwise tasks, the same base `mldse explore --preset
//! mapping` uses — or `{"base": {...}, "program": [...]}` with an
//! explicit spec, task list, and edge list.

use std::collections::HashMap;

use crate::eval::Registry;
use crate::hwir::{parse_spec_value, Hardware, PointKind};
use crate::mapping::{Mapping, MappingProgram, MappingState};
use crate::taskgraph::{ComputeCost, OpClass, TaskGraph, TaskId, TaskKind};
use crate::util::json::Json;

use super::diag::{self, Diagnostic};

/// The instantiation context a program is replayed against.
pub struct ProgramBase {
    pub hw: Hardware,
    pub graph: TaskGraph,
    pub mapping: Mapping,
}

/// The base used for bare-array programs: the same 2×2 DMC grid with
/// eight pre-placed elementwise tasks that backs the `mapping` preset.
pub fn demo_base() -> ProgramBase {
    let params = crate::arch::DmcParams {
        grid: (2, 2),
        with_dram: false,
        ..crate::arch::DmcParams::default()
    };
    let hw = params.build();
    let core0 = hw.points_of_kind("compute")[0];
    let mut graph = TaskGraph::new();
    let mut mapping = Mapping::new();
    for i in 0..8 {
        let mut c = ComputeCost::zero(OpClass::Elementwise);
        c.vec_flops = 40_000.0 * (1 + i % 4) as f64;
        let t = graph.add(format!("t{i}"), TaskKind::Compute(c));
        mapping.map(t, core0);
    }
    ProgramBase { hw, graph, mapping }
}

/// Parse the `"base"` object of a program document: a hardware `"spec"`,
/// a `"tasks"` array, and an optional `"edges"` array of `[src, dst]`
/// task-name pairs. Tasks may pre-place themselves with `"on": "<point
/// name>"` (the name must resolve to exactly one point).
pub fn base_from_json(v: &Json) -> crate::util::error::Result<ProgramBase> {
    let spec = v
        .get("spec")
        .ok_or_else(|| crate::format_err!("base missing \"spec\""))?;
    let hw = Hardware::build(parse_spec_value(spec).map_err(|e| crate::format_err!("{e}"))?);

    let mut graph = TaskGraph::new();
    let mut mapping = Mapping::new();
    let mut by_name: HashMap<String, TaskId> = HashMap::new();
    let tasks = v
        .get("tasks")
        .and_then(Json::as_arr)
        .ok_or_else(|| crate::format_err!("base missing \"tasks\" array"))?;
    for (i, t) in tasks.iter().enumerate() {
        let name = t
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| crate::format_err!("base task {i} missing \"name\""))?
            .to_string();
        crate::ensure!(
            !by_name.contains_key(&name),
            "base task name '{name}' is duplicated"
        );
        let kind = match t.get("kind").and_then(Json::as_str) {
            Some("compute") | None => {
                let mut c = ComputeCost::zero(OpClass::Elementwise);
                let f = |key: &str| t.get(key).and_then(Json::as_f64);
                let u = |key: &str| t.get(key).and_then(Json::as_u64);
                c.mac_flops = f("mac_flops").unwrap_or(0.0);
                c.vec_flops = f("vec_flops").unwrap_or(0.0);
                c.in_bytes = u("in_bytes").unwrap_or(0);
                c.out_bytes = u("out_bytes").unwrap_or(0);
                c.dram_bytes = u("dram_bytes").unwrap_or(0);
                TaskKind::Compute(c)
            }
            Some("storage") => TaskKind::Storage {
                bytes: t
                    .get("bytes")
                    .and_then(Json::as_u64)
                    .ok_or_else(|| crate::format_err!("storage task '{name}' missing bytes"))?,
            },
            Some("comm") => TaskKind::Comm {
                bytes: t
                    .get("bytes")
                    .and_then(Json::as_u64)
                    .ok_or_else(|| crate::format_err!("comm task '{name}' missing bytes"))?,
                hops: t.get("hops").and_then(Json::as_u64).unwrap_or(0),
                route: None,
            },
            Some(other) => crate::bail!(
                "base task '{name}': unknown kind '{other}' (valid: compute, storage, comm)"
            ),
        };
        let id = graph.add(name.clone(), kind);
        if let Some(on) = t.get("on").and_then(Json::as_str) {
            let points = hw.points_named(on);
            crate::ensure!(
                points.len() == 1,
                "base task '{name}': \"on\" point '{on}' resolves to {} points \
                 (must be exactly 1)",
                points.len()
            );
            mapping.map(id, points[0]);
        }
        by_name.insert(name, id);
    }
    if let Some(edges) = v.get("edges").and_then(Json::as_arr) {
        for (i, e) in edges.iter().enumerate() {
            let pair = e.as_arr().filter(|a| a.len() == 2).ok_or_else(|| {
                crate::format_err!("base edge {i} must be a [src, dst] name pair")
            })?;
            let mut ends = [TaskId(0); 2];
            for (slot, side) in pair.iter().zip(ends.iter_mut()) {
                let n = slot
                    .as_str()
                    .ok_or_else(|| crate::format_err!("base edge {i}: endpoints are names"))?;
                *side = *by_name
                    .get(n)
                    .ok_or_else(|| crate::format_err!("base edge {i}: unknown task '{n}'"))?;
            }
            graph.connect(ends[0], ends[1]);
        }
    }
    Ok(ProgramBase { hw, graph, mapping })
}

/// Run every mapping-program check on an already-parsed JSON document
/// (bare array or `{"base", "program"}`). Returns a sorted diagnostic
/// list (empty = clean).
pub fn check_program_doc(doc: &Json) -> Vec<Diagnostic> {
    let e020 = |msg: String| vec![Diagnostic::error(diag::E020_PROGRAM_INVALID, "", msg)];
    let (program, base) = if doc.as_arr().is_some() {
        match MappingProgram::from_json_value(doc) {
            Ok(p) => (p, demo_base()),
            Err(e) => return e020(format!("{e:#}")),
        }
    } else {
        let Some(base_v) = doc.get("base") else {
            return e020("program document must be a JSON array or {\"base\", \"program\"}".into());
        };
        let base = match base_from_json(base_v) {
            Ok(b) => b,
            Err(e) => return e020(format!("base: {e:#}")),
        };
        let Some(prog_v) = doc.get("program") else {
            return e020("program document missing \"program\" array".into());
        };
        match MappingProgram::from_json_value(prog_v) {
            Ok(p) => (p, base),
            Err(e) => return e020(format!("{e:#}")),
        }
    };

    let n_compute = base.hw.points_of_kind("compute").len();
    let holes = match program.resolved_holes(Some(n_compute)) {
        Ok(h) => h,
        Err(e) => return e020(format!("{e:#}")),
    };

    // Replay at the all-zeros binding: valid whenever every hole domain is
    // non-empty (which `resolved_holes` already guarantees).
    let binding = vec![0u32; holes.len()];
    let mut state = MappingState::new(base.graph.clone());
    state.mapping = base.mapping.clone();
    let evals = Registry::standard();
    if let Err(e) = program.replay(&mut state, &base.hw, &evals, &binding) {
        let mut d = vec![Diagnostic::error(
            diag::E024_REPLAY_FAILED,
            "",
            format!("{e:#}"),
        )];
        diag::sort(&mut d);
        return d;
    }

    let mut diags = Vec::new();
    lint_deadlock(&state.graph, &mut diags);
    lint_mapping(&state, &base.hw, &mut diags);
    lint_disabled(&state.graph, &mut diags);
    lint_feasibility(&state, &base.hw, &evals, &mut diags);
    diag::sort(&mut diags);
    diags
}

/// E021: cycle detection over the sync-edge closure. All sync tasks
/// sharing a `sync_id` complete together, so they are contracted into a
/// single node; any remaining cycle over the enabled tasks deadlocks the
/// simulator.
fn lint_deadlock(graph: &TaskGraph, diags: &mut Vec<Diagnostic>) {
    // Node index per enabled task, contracting same-sync_id tasks.
    let mut node_of: HashMap<TaskId, usize> = HashMap::new();
    let mut sync_node: HashMap<u32, usize> = HashMap::new();
    let mut members: Vec<Vec<TaskId>> = Vec::new();
    for t in graph.iter().filter(|t| t.enabled) {
        let node = match &t.kind {
            TaskKind::Sync { sync_id } => *sync_node.entry(*sync_id).or_insert_with(|| {
                members.push(Vec::new());
                members.len() - 1
            }),
            _ => {
                members.push(Vec::new());
                members.len() - 1
            }
        };
        members[node].push(t.id);
        node_of.insert(t.id, node);
    }
    let n = members.len();
    let mut succs: Vec<Vec<usize>> = vec![Vec::new(); n];
    let mut indeg = vec![0usize; n];
    for t in graph.iter().filter(|t| t.enabled) {
        let a = node_of[&t.id];
        for s in graph.successors(t.id) {
            if let Some(&b) = node_of.get(s) {
                if a != b && !succs[a].contains(&b) {
                    succs[a].push(b);
                    indeg[b] += 1;
                }
            }
        }
    }
    // Kahn over the contracted graph; leftovers contain every cycle.
    let mut queue: Vec<usize> = (0..n).filter(|&i| indeg[i] == 0).collect();
    let mut removed = vec![false; n];
    while let Some(i) = queue.pop() {
        removed[i] = true;
        for &s in &succs[i] {
            indeg[s] -= 1;
            if indeg[s] == 0 {
                queue.push(s);
            }
        }
    }
    if removed.iter().all(|r| *r) {
        return;
    }
    let mut witness: Vec<&str> = (0..n)
        .filter(|&i| !removed[i])
        .flat_map(|i| members[i].iter().map(|t| graph.task(*t).name.as_str()))
        .collect();
    witness.sort_unstable();
    let shown = witness.len().min(8);
    let mut list = witness[..shown].join(", ");
    if witness.len() > shown {
        list.push_str(&format!(", … ({} more)", witness.len() - shown));
    }
    diags.push(Diagnostic::error(
        diag::E021_DEADLOCK_CYCLE,
        "",
        format!(
            "dependency cycle through the sync-edge closure involving tasks: {list}; \
             the simulator would deadlock"
        ),
    ));
}

/// E022 (enabled task unmapped) and E023 (kind-incompatible placement) —
/// the same rules as `Mapping::validate`, but reported per task with
/// stable codes.
fn lint_mapping(state: &MappingState, hw: &Hardware, diags: &mut Vec<Diagnostic>) {
    for task in state.graph.iter().filter(|t| t.enabled) {
        // Originals of decomposed comm edges are exempt: their subs carry
        // the placement.
        if state.mapping.edge_decomposition(task.id).is_some() {
            continue;
        }
        match state.mapping.point_of(task.id) {
            None => diags.push(Diagnostic::error(
                diag::E022_UNMAPPED_TASK,
                task.name.clone(),
                format!("enabled task {} ({}) is unmapped", task.id, task.name),
            )),
            Some(p) => {
                let kind = &hw.entry(p).point.kind;
                let ok = match &task.kind {
                    TaskKind::Compute(_) => kind.is_compute(),
                    TaskKind::Storage { .. } => kind.is_memory(),
                    TaskKind::Comm { .. } => kind.is_comm() || kind.is_memory(),
                    TaskKind::Sync { .. } => true,
                };
                if !ok {
                    diags.push(Diagnostic::error(
                        diag::E023_KIND_MISMATCH,
                        task.name.clone(),
                        format!(
                            "{} task {} ({}) mapped to {} point {}",
                            task.kind.kind_name(),
                            task.id,
                            task.name,
                            kind.kind_name(),
                            hw.entry(p).addr,
                        ),
                    ));
                }
            }
        }
    }
}

/// W025: a disabled task whose consumers still run. The simulator treats
/// the dependency as satisfied, so the consumer reads data that was never
/// produced.
fn lint_disabled(graph: &TaskGraph, diags: &mut Vec<Diagnostic>) {
    for task in graph.iter().filter(|t| !t.enabled) {
        let live: Vec<&str> = graph
            .successors(task.id)
            .iter()
            .filter(|s| graph.task(**s).enabled)
            .map(|s| graph.task(*s).name.as_str())
            .collect();
        if !live.is_empty() {
            diags.push(Diagnostic::warning(
                diag::W025_DISABLED_LIVE_CONSUMERS,
                task.name.clone(),
                format!(
                    "disabled task {} ({}) still has enabled consumers: {}",
                    task.id,
                    task.name,
                    live.join(", ")
                ),
            ));
        }
    }
}

/// W030 (footprint vs. capacity) and W031 (link-bound flow) — lower-bound
/// feasibility from static costs, no simulation.
fn lint_feasibility(
    state: &MappingState,
    hw: &Hardware,
    evals: &Registry,
    diags: &mut Vec<Diagnostic>,
) {
    // Per-point aggregates over enabled mapped tasks.
    let mut storage_bytes: HashMap<crate::hwir::PointId, u64> = HashMap::new();
    let mut comm_bytes: HashMap<crate::hwir::PointId, u64> = HashMap::new();
    let mut compute_cycles: HashMap<crate::hwir::PointId, f64> = HashMap::new();
    for (t, p) in state.mapping.mapped_tasks() {
        let Some(task) = state.graph.get(t).filter(|t| t.enabled) else {
            continue;
        };
        let entry = hw.entry(p);
        match &task.kind {
            TaskKind::Compute(c) => {
                if let Some(lmem) = entry.point.kind.as_compute().and_then(|a| a.lmem.as_ref()) {
                    if lmem.capacity > 0 && c.local_bytes() > lmem.capacity {
                        diags.push(Diagnostic::warning(
                            diag::W030_OVER_CAPACITY,
                            task.name.clone(),
                            format!(
                                "task {} ({}) needs {} bytes of local memory but point {} \
                                 ({}) has lmem capacity {}",
                                task.id,
                                task.name,
                                c.local_bytes(),
                                entry.addr,
                                entry.point.name,
                                lmem.capacity,
                            ),
                        ));
                    }
                }
                *compute_cycles.entry(p).or_insert(0.0) += evals.demand(task, entry).total();
            }
            TaskKind::Storage { bytes } => {
                *storage_bytes.entry(p).or_insert(0) += bytes;
            }
            TaskKind::Comm { bytes, .. } => {
                if entry.point.kind.is_comm() {
                    *comm_bytes.entry(p).or_insert(0) += bytes;
                }
            }
            TaskKind::Sync { .. } => {}
        }
    }

    for (p, bytes) in &storage_bytes {
        let entry = hw.entry(*p);
        if let Some(mem) = entry.point.kind.as_memory() {
            if mem.capacity > 0 && *bytes > mem.capacity {
                diags.push(Diagnostic::warning(
                    diag::W030_OVER_CAPACITY,
                    format!("{}", entry.addr),
                    format!(
                        "storage residency on point {} ({}) is {} bytes but capacity is {}",
                        entry.addr, entry.point.name, bytes, mem.capacity,
                    ),
                ));
            }
        }
    }

    // Link-bound: total flow cycles through a comm point exceed the busiest
    // compute point's cycle lower bound — the link, not compute, sets the
    // makespan floor.
    let compute_floor = compute_cycles.values().fold(0.0f64, |a, b| a.max(*b));
    if compute_floor > 0.0 {
        for (p, bytes) in &comm_bytes {
            let entry = hw.entry(*p);
            let Some(comm) = entry.point.kind.as_comm() else {
                continue;
            };
            if comm.link_bandwidth <= 0.0 {
                continue;
            }
            let flow_cycles = *bytes as f64 / comm.link_bandwidth;
            if flow_cycles > compute_floor {
                diags.push(Diagnostic::warning(
                    diag::W031_LINK_BOUND,
                    format!("{}", entry.addr),
                    format!(
                        "flow of {} bytes on comm point {} ({}) needs {:.0} cycles at \
                         {} B/cycle, exceeding the busiest compute point's {:.0}-cycle \
                         lower bound (link-bound mapping)",
                        bytes,
                        entry.addr,
                        entry.point.name,
                        flow_cycles,
                        comm.link_bandwidth,
                        compute_floor,
                    ),
                ));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analyze::diag::Severity;

    fn check(text: &str) -> Vec<Diagnostic> {
        check_program_doc(&Json::parse(text).unwrap())
    }

    #[test]
    fn clean_demo_program_is_clean() {
        let d = check(
            r#"[{"op": "map_node", "task": "heaviest",
                 "point": {"hole": "p0", "points": "compute"}}]"#,
        );
        assert!(d.is_empty(), "{d:?}");
    }

    #[test]
    fn bad_program_is_e020() {
        let d = check(r#"[{"op": "map_node", "task": "heaviest", "point": {"hole": "h", "choices": []}}]"#);
        assert_eq!(d[0].code, diag::E020_PROGRAM_INVALID, "{d:?}");
        assert_eq!(d[0].severity, Severity::Error);
        let d = check(r#"{"program": []}"#);
        assert_eq!(d[0].code, diag::E020_PROGRAM_INVALID, "{d:?}");
    }

    #[test]
    fn barrier_cycle_is_e021() {
        // a -> b, then a barrier ordering "b completes before a runs":
        // a -> b -> sync -> a is a deadlock.
        let d = check(
            r#"{"base": {
                  "spec": {"matrix": {"name": "chip", "dims": [2],
                    "comms": [{"name": "noc", "topology": "mesh", "link_bandwidth": 32}],
                    "fill": {"point": {"name": "core", "kind": "compute",
                                       "systolic": [4, 4], "vector_lanes": 8}}}},
                  "tasks": [
                    {"name": "a", "kind": "compute", "vec_flops": 1000, "on": "core"},
                    {"name": "b", "kind": "compute", "vec_flops": 1000, "on": "core"}],
                  "edges": [["a", "b"]]},
                "program": [{"op": "barrier", "after": "b", "before": "a"}]}"#,
        );
        assert!(d.iter().any(|x| x.code == diag::E021_DEADLOCK_CYCLE), "{d:?}");
    }

    #[test]
    fn unmapped_task_is_e022() {
        let d = check(
            r#"{"base": {
                  "spec": {"matrix": {"name": "chip", "dims": [1],
                    "fill": {"point": {"name": "core", "kind": "compute",
                                       "systolic": [4, 4]}}}},
                  "tasks": [{"name": "a", "kind": "compute", "vec_flops": 1000}]},
                "program": []}"#,
        );
        assert!(d.iter().any(|x| x.code == diag::E022_UNMAPPED_TASK), "{d:?}");
    }

    #[test]
    fn kind_mismatch_is_e023() {
        let d = check(
            r#"{"base": {
                  "spec": {"matrix": {"name": "chip", "dims": [1],
                    "fill": {"point": {"name": "core", "kind": "compute",
                                       "systolic": [4, 4]}}}},
                  "tasks": [{"name": "w", "kind": "storage", "bytes": 64, "on": "core"}]},
                "program": []}"#,
        );
        assert!(d.iter().any(|x| x.code == diag::E023_KIND_MISMATCH), "{d:?}");
    }

    #[test]
    fn replay_failure_is_e024() {
        let d = check(r#"[{"op": "map_node", "task": "heaviest", "point": 99}]"#);
        assert_eq!(d.len(), 1, "{d:?}");
        assert_eq!(d[0].code, diag::E024_REPLAY_FAILED);
        assert!(d[0].message.contains("out of range"), "{}", d[0].message);
    }

    #[test]
    fn disabled_with_live_consumers_is_w025() {
        let d = check(
            r#"{"base": {
                  "spec": {"matrix": {"name": "chip", "dims": [2],
                    "comms": [{"name": "noc", "topology": "mesh", "link_bandwidth": 32}],
                    "fill": {"point": {"name": "core", "kind": "compute",
                                       "systolic": [4, 4], "vector_lanes": 8}}}},
                  "tasks": [
                    {"name": "a", "kind": "compute", "vec_flops": 1000, "on": "core"},
                    {"name": "b", "kind": "compute", "vec_flops": 1000, "on": "core"}],
                  "edges": [["a", "b"]]},
                "program": [{"op": "disable", "task": "a"}]}"#,
        );
        assert!(d.iter().any(|x| x.code == diag::W025_DISABLED_LIVE_CONSUMERS), "{d:?}");
        // The disabled task is exempt from the unmapped check... but here it
        // IS mapped, so just confirm no spurious errors.
        assert!(!diag::has_errors(&d), "{d:?}");
    }

    #[test]
    fn over_capacity_tile_is_w030() {
        let d = check(
            r#"{"base": {
                  "spec": {"matrix": {"name": "chip", "dims": [1],
                    "fill": {"point": {"name": "core", "kind": "compute",
                      "systolic": [4, 4], "vector_lanes": 8,
                      "lmem": {"capacity": 64, "bandwidth": 16}}}}},
                  "tasks": [{"name": "big", "kind": "compute", "vec_flops": 1000,
                             "in_bytes": 4096, "out_bytes": 4096, "on": "core"}]},
                "program": []}"#,
        );
        assert!(d.iter().any(|x| x.code == diag::W030_OVER_CAPACITY), "{d:?}");
    }

    #[test]
    fn link_bound_flow_is_w031() {
        let d = check(
            r#"{"base": {
                  "spec": {"matrix": {"name": "chip", "dims": [2],
                    "comms": [{"name": "noc", "topology": "mesh", "link_bandwidth": 1}],
                    "fill": {"point": {"name": "core", "kind": "compute",
                                       "systolic": [4, 4], "vector_lanes": 8}}}},
                  "tasks": [
                    {"name": "a", "kind": "compute", "vec_flops": 100, "on": "core"},
                    {"name": "xfer", "kind": "comm", "bytes": 1000000000, "on": "noc"}]},
                "program": []}"#,
        );
        assert!(d.iter().any(|x| x.code == diag::W031_LINK_BOUND), "{d:?}");
    }

    #[test]
    fn base_errors_are_e020() {
        let d = check(r#"{"base": {"tasks": []}, "program": []}"#);
        assert_eq!(d[0].code, diag::E020_PROGRAM_INVALID);
        let d = check(
            r#"{"base": {
                  "spec": {"matrix": {"name": "c", "dims": [1],
                    "fill": {"point": {"name": "core", "kind": "compute"}}}},
                  "tasks": [{"name": "a", "on": "nope"}]},
                "program": []}"#,
        );
        assert_eq!(d[0].code, diag::E020_PROGRAM_INVALID, "{d:?}");
    }
}
