//! PJRT-backed evaluator: executes the AOT-compiled JAX/Pallas roofline
//! evaluator (Layer 1/2 of this repo) through the [`crate::runtime`] bridge.
//!
//! Demonstrates the paper's evaluator pluggability: bind
//! `SpacePoint::evaluator = "pjrt"` and register a [`PjrtEvaluator`] in the
//! [`super::Registry`]. Task descriptors are batched (the artifact is
//! lowered at a fixed batch size), results are cached by
//! `(descriptor, point)` key, and the coordinator pre-warms the cache for a
//! whole task graph before simulation so the hot loop never blocks on XLA.
//!
//! Descriptor layout (must match `python/compile/model.py`):
//!
//! | idx | field |
//! |-----|------------|
//! | 0   | op code    |
//! | 1   | mac_flops  |
//! | 2   | vec_flops  |
//! | 3   | in_bytes   |
//! | 4   | out_bytes  |
//! | 5–7 | m, n, k    |
//!
//! Hardware-parameter vector layout:
//!
//! | idx | field |
//! |-----|---------------------|
//! | 0   | systolic rows R     |
//! | 1   | systolic cols C     |
//! | 2   | vector lanes        |
//! | 3   | lmem bandwidth      |
//! | 4   | lmem latency        |
//! | 5   | pipeline fill       |
//! | 6   | vector efficiency   |

use std::collections::HashMap;
use std::sync::Mutex;

use crate::util::error::{Context, Result};

use crate::hwir::{PointEntry, PointKind};
use crate::runtime::{Executable, Runtime};
use crate::taskgraph::{ComputeCost, Task, TaskKind};

use super::roofline::RooflineEvaluator;
use super::{Demand, Evaluator};

/// Number of per-task descriptor fields.
pub const DESC_FIELDS: usize = 8;
/// Number of hardware-parameter fields.
pub const HW_FIELDS: usize = 7;
/// Batch size the artifact is lowered at.
pub const BATCH: usize = 128;

/// Cache key: quantized descriptor + point id.
type Key = (u32, [u32; 3], u64, u64, u64, u64, u64, u32);

/// Evaluator backed by the AOT-compiled XLA computation.
pub struct PjrtEvaluator {
    exe: Executable,
    cache: Mutex<HashMap<Key, f64>>,
    /// Fallback for task kinds the artifact does not model (comm tasks).
    fallback: RooflineEvaluator,
    /// Cache statistics (hits, misses).
    stats: Mutex<(u64, u64)>,
}

impl PjrtEvaluator {
    /// Load the evaluator artifact (`evaluator_b128.hlo.txt`) from the
    /// artifacts directory.
    pub fn load(rt: &Runtime) -> Result<Self> {
        let path = crate::runtime::artifacts_dir().join(format!("evaluator_b{BATCH}.hlo.txt"));
        let exe = rt
            .load_hlo_text(&path)
            .with_context(|| format!("loading evaluator artifact {}", path.display()))?;
        Ok(PjrtEvaluator {
            exe,
            cache: Mutex::new(HashMap::new()),
            fallback: RooflineEvaluator::default(),
            stats: Mutex::new((0, 0)),
        })
    }

    fn descriptor(cost: &ComputeCost) -> [f32; DESC_FIELDS] {
        [
            cost.op.code() as f32,
            cost.mac_flops as f32,
            cost.vec_flops as f32,
            cost.in_bytes as f32,
            cost.out_bytes as f32,
            cost.dims[0] as f32,
            cost.dims[1] as f32,
            cost.dims[2] as f32,
        ]
    }

    fn hw_params(point: &PointEntry) -> Option<[f32; HW_FIELDS]> {
        match &point.point.kind {
            PointKind::Compute(a) => {
                let (bw, lat) = a
                    .lmem
                    .as_ref()
                    .map(|m| (m.bandwidth as f32, m.latency as f32))
                    .unwrap_or((f32::INFINITY, 0.0));
                Some([
                    a.systolic.0 as f32,
                    a.systolic.1 as f32,
                    a.vector_lanes as f32,
                    bw,
                    lat,
                    1.0,  // pipeline fill (matches RooflineConfig::default)
                    0.75, // vector efficiency
                ])
            }
            _ => None,
        }
    }

    fn key(cost: &ComputeCost, point: &PointEntry) -> Key {
        let (op, dims, ib, ob, db, mf, vf) = cost.dedup_key();
        (op, dims, ib, ob, db, mf, vf, point.id.0)
    }

    /// Evaluate a batch of compute costs on one point, filling the cache.
    pub fn prewarm_batch(&self, costs: &[ComputeCost], point: &PointEntry) -> Result<()> {
        let Some(hwp) = Self::hw_params(point) else {
            return Ok(());
        };
        for chunk in costs.chunks(BATCH) {
            let mut desc = vec![0f32; BATCH * DESC_FIELDS];
            for (i, c) in chunk.iter().enumerate() {
                desc[i * DESC_FIELDS..(i + 1) * DESC_FIELDS].copy_from_slice(&Self::descriptor(c));
            }
            let out = self
                .exe
                .run_f32(&[(&desc, &[BATCH, DESC_FIELDS]), (&hwp, &[HW_FIELDS])])?;
            let lat = &out[0];
            let mut cache = self.cache.lock().unwrap();
            for (i, c) in chunk.iter().enumerate() {
                cache.insert(Self::key(c, point), lat[i] as f64);
            }
        }
        Ok(())
    }

    /// Pre-evaluate every enabled compute task of a graph on its mapped
    /// point so the simulation loop is cache-hit only.
    pub fn prewarm(
        &self,
        graph: &crate::taskgraph::TaskGraph,
        mapping: &crate::mapping::Mapping,
        hw: &crate::hwir::Hardware,
    ) -> Result<usize> {
        // group unique costs per point
        let mut per_point: HashMap<u32, Vec<ComputeCost>> = HashMap::new();
        let mut seen: std::collections::HashSet<Key> = std::collections::HashSet::new();
        for task in graph.iter() {
            if !task.enabled {
                continue;
            }
            if let TaskKind::Compute(cost) = &task.kind {
                if let Some(pid) = mapping.point_of(task.id) {
                    let entry = hw.entry(pid);
                    let key = Self::key(cost, entry);
                    if seen.insert(key) {
                        per_point.entry(pid.0).or_default().push(*cost);
                    }
                }
            }
        }
        let mut n = 0;
        for (pid, costs) in per_point {
            let entry = hw.entry(crate::hwir::PointId(pid));
            n += costs.len();
            self.prewarm_batch(&costs, entry)?;
        }
        Ok(n)
    }

    /// (hits, misses) counters.
    pub fn cache_stats(&self) -> (u64, u64) {
        *self.stats.lock().unwrap()
    }
}

impl Evaluator for PjrtEvaluator {
    fn demand(&self, task: &Task, point: &PointEntry) -> Demand {
        match (&task.kind, &point.point.kind) {
            (TaskKind::Compute(cost), PointKind::Compute(_)) => {
                let key = Self::key(cost, point);
                if let Some(v) = self.cache.lock().unwrap().get(&key) {
                    self.stats.lock().unwrap().0 += 1;
                    return Demand::new(*v, 0.0);
                }
                self.stats.lock().unwrap().1 += 1;
                // Cache miss: evaluate a batch of one (padded).
                match self.prewarm_batch(&[*cost], point) {
                    Ok(()) => {
                        let v = *self.cache.lock().unwrap().get(&key).unwrap();
                        Demand::new(v, 0.0)
                    }
                    Err(e) => {
                        crate::log_error!("pjrt evaluation failed: {e:#}; using roofline");
                        self.fallback.demand(task, point)
                    }
                }
            }
            _ => self.fallback.demand(task, point),
        }
    }

    fn name(&self) -> &str {
        "pjrt"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hwir::{
        ComputeAttrs, Coord, Element, Hardware, MemoryAttrs, SpaceMatrix, SpacePoint,
    };
    use crate::taskgraph::{OpClass, TaskGraph};

    fn hw() -> Hardware {
        let mut m = SpaceMatrix::new("chip", vec![1]);
        m.set(
            Coord::new(vec![0]),
            Element::Point(SpacePoint::compute(
                "core",
                ComputeAttrs::new((32, 32), 128).with_lmem(MemoryAttrs::new(1 << 21, 64.0, 2)),
            )),
        );
        Hardware::build(m)
    }

    fn mm_cost(m: u32, n: u32, k: u32) -> ComputeCost {
        let mut c = ComputeCost::zero(OpClass::MatMul);
        c.dims = [m, n, k];
        c.mac_flops = 2.0 * m as f64 * n as f64 * k as f64;
        c.in_bytes = 2 * (m as u64 * k as u64 + k as u64 * n as u64);
        c.out_bytes = 2 * m as u64 * n as u64;
        c
    }

    /// Requires `make artifacts` and a real PJRT backend; skips otherwise.
    #[test]
    fn pjrt_matches_rust_roofline() {
        let art = crate::runtime::artifacts_dir().join(format!("evaluator_b{BATCH}.hlo.txt"));
        if !art.exists() {
            eprintln!("skipping: artifact missing (run `make artifacts`)");
            return;
        }
        let Ok(rt) = Runtime::cpu() else {
            eprintln!("skipping: PJRT backend unavailable (null backend build)");
            return;
        };
        let ev = PjrtEvaluator::load(&rt).unwrap();
        let hw = hw();
        let entry = hw.entries().next().unwrap();
        let rust_ev = RooflineEvaluator::default();
        let mut g = TaskGraph::new();
        for (m, n, k) in [(32, 32, 64), (128, 128, 128), (33, 65, 100), (2048, 4096, 4096)] {
            let t = g.add("mm", TaskKind::Compute(mm_cost(m, n, k)));
            let want = rust_ev.demand(g.task(t), entry).total();
            let got = ev.demand(g.task(t), entry).total();
            let rel = (got - want).abs() / want.max(1.0);
            assert!(
                rel < 1e-3,
                "({m},{n},{k}): pjrt {got} vs rust {want} (rel {rel})"
            );
        }
        let (hits, misses) = ev.cache_stats();
        assert!(misses > 0 && hits + misses >= 4);
    }
}
