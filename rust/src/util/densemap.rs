//! Dense `Vec`-backed maps over index-like keys (`TaskId`, `PointId`).
//!
//! The simulator result maps used to be `HashMap`s keyed by the dense id
//! types, which costs a hash + allocation per insert on the DSE hot path
//! and iterates in a nondeterministic order. [`DenseMap`] stores values in
//! a plain `Vec<Option<V>>` indexed by the key's integer index: O(1)
//! unhashed access, one allocation amortized over the whole map, and
//! stable (index-order) iteration — which also makes derived artifacts
//! like the memory-violation list deterministic.

use std::fmt;
use std::marker::PhantomData;
use std::ops::Index;

/// An index-like key: a newtype over a small dense integer id.
pub trait DenseKey: Copy {
    fn dense_index(self) -> usize;
    fn from_dense_index(i: usize) -> Self;
}

/// A map from a [`DenseKey`] to `V`, backed by a `Vec<Option<V>>`.
#[derive(Clone)]
pub struct DenseMap<K, V> {
    slots: Vec<Option<V>>,
    len: usize,
    _key: PhantomData<K>,
}

impl<K, V> Default for DenseMap<K, V> {
    fn default() -> Self {
        DenseMap {
            slots: Vec::new(),
            len: 0,
            _key: PhantomData,
        }
    }
}

impl<K: DenseKey, V> DenseMap<K, V> {
    pub fn new() -> Self {
        Self::default()
    }

    /// Pre-size the backing vector for keys `0..n` (avoids regrowth when
    /// the caller knows the index universe, e.g. `hw.num_points()`).
    pub fn with_capacity(n: usize) -> Self {
        let mut slots = Vec::new();
        slots.resize_with(n, || None);
        DenseMap {
            slots,
            len: 0,
            _key: PhantomData,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn contains_key(&self, k: &K) -> bool {
        self.get(k).is_some()
    }

    pub fn get(&self, k: &K) -> Option<&V> {
        self.slots.get(k.dense_index()).and_then(|s| s.as_ref())
    }

    pub fn get_mut(&mut self, k: &K) -> Option<&mut V> {
        self.slots.get_mut(k.dense_index()).and_then(|s| s.as_mut())
    }

    pub fn insert(&mut self, k: K, v: V) -> Option<V> {
        let i = k.dense_index();
        if i >= self.slots.len() {
            self.slots.resize_with(i + 1, || None);
        }
        let old = self.slots[i].replace(v);
        if old.is_none() {
            self.len += 1;
        }
        old
    }

    /// The value at `k`, inserting `default` first when absent — the dense
    /// analogue of `HashMap::entry(k).or_insert(default)`.
    pub fn entry_or(&mut self, k: K, default: V) -> &mut V {
        let i = k.dense_index();
        if i >= self.slots.len() {
            self.slots.resize_with(i + 1, || None);
        }
        let slot = &mut self.slots[i];
        if slot.is_none() {
            *slot = Some(default);
            self.len += 1;
        }
        slot.as_mut().expect("slot just filled")
    }

    /// Entries in key-index order.
    pub fn iter(&self) -> impl Iterator<Item = (K, &V)> {
        self.slots
            .iter()
            .enumerate()
            .filter_map(|(i, s)| s.as_ref().map(|v| (K::from_dense_index(i), v)))
    }

    pub fn keys(&self) -> impl Iterator<Item = K> + '_ {
        self.iter().map(|(k, _)| k)
    }

    pub fn values(&self) -> impl Iterator<Item = &V> {
        self.slots.iter().filter_map(|s| s.as_ref())
    }
}

impl<K: DenseKey, V: PartialEq> PartialEq for DenseMap<K, V> {
    /// Logical equality: same key set with equal values, regardless of
    /// backing-vector capacity.
    fn eq(&self, other: &Self) -> bool {
        if self.len != other.len {
            return false;
        }
        self.slots
            .iter()
            .enumerate()
            .filter(|(_, s)| s.is_some())
            .all(|(i, s)| other.slots.get(i).map(|o| o.as_ref()) == Some(s.as_ref()))
    }
}

impl<K: DenseKey, V> Index<&K> for DenseMap<K, V> {
    type Output = V;

    fn index(&self, k: &K) -> &V {
        self.get(k).expect("no entry for key in DenseMap")
    }
}

impl<K: DenseKey, V> Index<K> for DenseMap<K, V> {
    type Output = V;

    fn index(&self, k: K) -> &V {
        self.get(&k).expect("no entry for key in DenseMap")
    }
}

impl<'a, K: DenseKey, V> IntoIterator for &'a DenseMap<K, V> {
    type Item = (K, &'a V);
    type IntoIter = Box<dyn Iterator<Item = (K, &'a V)> + 'a>;

    fn into_iter(self) -> Self::IntoIter {
        Box::new(self.iter())
    }
}

impl<K: DenseKey, V> FromIterator<(K, V)> for DenseMap<K, V> {
    fn from_iter<I: IntoIterator<Item = (K, V)>>(iter: I) -> Self {
        let mut m = DenseMap::new();
        for (k, v) in iter {
            m.insert(k, v);
        }
        m
    }
}

impl<K: DenseKey + fmt::Debug, V: fmt::Debug> fmt::Debug for DenseMap<K, V> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_map().entries(self.iter()).finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Debug, Clone, Copy, PartialEq, Eq)]
    struct Id(u32);
    impl DenseKey for Id {
        fn dense_index(self) -> usize {
            self.0 as usize
        }
        fn from_dense_index(i: usize) -> Self {
            Id(i as u32)
        }
    }

    #[test]
    fn insert_get_update() {
        let mut m: DenseMap<Id, f64> = DenseMap::new();
        assert!(m.is_empty());
        assert_eq!(m.insert(Id(3), 1.5), None);
        assert_eq!(m.insert(Id(0), 2.5), None);
        assert_eq!(m.len(), 2);
        assert_eq!(m.get(&Id(3)), Some(&1.5));
        assert_eq!(m.get(&Id(1)), None);
        assert_eq!(m.insert(Id(3), 9.0), Some(1.5));
        assert_eq!(m.len(), 2);
        assert_eq!(m[&Id(3)], 9.0);
        assert_eq!(m[Id(0)], 2.5);
    }

    #[test]
    fn entry_or_accumulates() {
        let mut m: DenseMap<Id, f64> = DenseMap::new();
        *m.entry_or(Id(5), 0.0) += 2.0;
        *m.entry_or(Id(5), 0.0) += 3.0;
        assert_eq!(m[&Id(5)], 5.0);
        assert_eq!(m.len(), 1);
    }

    #[test]
    fn iteration_is_index_ordered() {
        let mut m: DenseMap<Id, u32> = DenseMap::new();
        m.insert(Id(7), 70);
        m.insert(Id(2), 20);
        m.insert(Id(4), 40);
        let keys: Vec<u32> = m.keys().map(|k| k.0).collect();
        assert_eq!(keys, vec![2, 4, 7]);
        let vals: Vec<u32> = m.values().copied().collect();
        assert_eq!(vals, vec![20, 40, 70]);
        let pairs: Vec<(u32, u32)> = (&m).into_iter().map(|(k, v)| (k.0, *v)).collect();
        assert_eq!(pairs, vec![(2, 20), (4, 40), (7, 70)]);
    }

    #[test]
    fn equality_ignores_capacity() {
        let mut a: DenseMap<Id, u32> = DenseMap::with_capacity(64);
        let mut b: DenseMap<Id, u32> = DenseMap::new();
        a.insert(Id(1), 10);
        b.insert(Id(1), 10);
        assert_eq!(a, b);
        b.insert(Id(9), 90);
        assert_ne!(a, b);
        a.insert(Id(9), 91);
        assert_ne!(a, b);
        a.insert(Id(9), 90);
        assert_eq!(a, b);
    }

    #[test]
    fn from_iter_collects() {
        let m: DenseMap<Id, u32> = [(Id(1), 1), (Id(0), 0)].into_iter().collect();
        assert_eq!(m.len(), 2);
        assert_eq!(m[&Id(0)], 0);
    }
}
