//! Distributed many-core (DMC) architecture template (paper Fig. 9(b)).
//!
//! A chip of `grid` cores — each a compute `SpacePoint` with systolic array,
//! vector unit and private local memory — connected by a 2D-mesh NoC, with
//! an off-chip DRAM channel at board level. Parameters follow the paper's
//! IPU-like instantiation (footnote 2: "parameters resembling a Graphcore
//! IPU, without directly modeling it"; 128 tiles at 152 B/cycle local
//! bandwidth, footnote 3).

use crate::cost::AreaModel;
use crate::hwir::{
    CommAttrs, ComputeAttrs, Coord, Element, Hardware, MemoryAttrs, SpaceMatrix, SpacePoint,
    Topology,
};
use crate::util::error::Result;

/// DMC design parameters (bandwidths in bytes/cycle, capacities in bytes).
#[derive(Debug, Clone, PartialEq)]
pub struct DmcParams {
    /// Core grid (rows, cols).
    pub grid: (usize, usize),
    pub systolic: (u32, u32),
    pub vector_lanes: u32,
    pub lmem_capacity: u64,
    pub lmem_bandwidth: f64,
    pub lmem_latency: u64,
    pub noc_bandwidth: f64,
    pub noc_latency: u64,
    pub dram_capacity: u64,
    pub dram_bandwidth: f64,
    pub dram_latency: u64,
    /// Attach an off-chip DRAM channel (disable for chiplet use inside
    /// MPMC packages where memory is fully on-chip).
    pub with_dram: bool,
}

impl Default for DmcParams {
    fn default() -> Self {
        DmcParams {
            grid: (16, 8), // 128 cores
            systolic: (64, 64),
            vector_lanes: 512,
            lmem_capacity: 2 << 20,
            lmem_bandwidth: 152.0,
            lmem_latency: 2,
            noc_bandwidth: 32.0,
            noc_latency: 1,
            dram_capacity: 16 << 30,
            dram_bandwidth: 2048.0, // HBM2e-class at 1 GHz
            dram_latency: 100,
            with_dram: true,
        }
    }
}

impl DmcParams {
    /// Number of cores.
    pub fn cores(&self) -> usize {
        self.grid.0 * self.grid.1
    }

    /// Total on-chip memory.
    pub fn total_lmem(&self) -> u64 {
        self.cores() as u64 * self.lmem_capacity
    }

    /// The four Table-2 compute-memory configurations (1-indexed).
    ///
    /// The index arrives from user input (`mldse simulate --config`, JSON
    /// space files), so out-of-range values are a configuration *error*,
    /// never a panic.
    pub fn table2(config: usize) -> Result<DmcParams> {
        let base = DmcParams::default();
        Ok(match config {
            1 => DmcParams {
                lmem_capacity: 1 << 20,
                systolic: (128, 128),
                vector_lanes: 512,
                ..base
            },
            2 => DmcParams {
                lmem_capacity: 2 << 20,
                systolic: (64, 64),
                vector_lanes: 512,
                ..base
            },
            3 => DmcParams {
                lmem_capacity: 5 << 19, // 2.5 MB
                systolic: (32, 32),
                vector_lanes: 128,
                ..base
            },
            4 => DmcParams {
                lmem_capacity: 3 << 20,
                systolic: (16, 16),
                vector_lanes: 128,
                ..base
            },
            other => crate::bail!("DMC table2 config {other} out of range 1..=4"),
        })
    }

    /// The core-array `SpaceMatrix` (chip without board/DRAM wrapper).
    pub fn chip_matrix(&self, name: &str) -> SpaceMatrix {
        let mut chip = SpaceMatrix::new(name, vec![self.grid.0, self.grid.1]);
        let core = SpacePoint::compute(
            "core",
            ComputeAttrs::new(self.systolic, self.vector_lanes).with_lmem(MemoryAttrs::new(
                self.lmem_capacity,
                self.lmem_bandwidth,
                self.lmem_latency,
            )),
        );
        for r in 0..self.grid.0 {
            for c in 0..self.grid.1 {
                chip.set(
                    Coord::new(vec![r as u32, c as u32]),
                    Element::Point(core.clone()),
                );
            }
        }
        chip.add_comm(SpacePoint::comm(
            "noc",
            CommAttrs::new(Topology::Mesh, self.noc_bandwidth, self.noc_latency),
        ));
        chip
    }

    /// Build the operable hardware: `board -> { chip, dram? }`.
    pub fn build(&self) -> Hardware {
        let chip = self.chip_matrix("chip");
        let cells = if self.with_dram { 2 } else { 1 };
        let mut board = SpaceMatrix::new("board", vec![cells]);
        board.set(Coord::new(vec![0]), Element::Matrix(chip));
        if self.with_dram {
            board.set(
                Coord::new(vec![1]),
                Element::Point(SpacePoint::dram(
                    "dram",
                    MemoryAttrs::new(self.dram_capacity, self.dram_bandwidth, self.dram_latency),
                )),
            );
        }
        // chip<->DRAM PHY; generous so the DRAM channel itself dominates
        board.add_comm(SpacePoint::comm(
            "phy",
            CommAttrs::new(Topology::Bus, 4096.0, 1),
        ));
        Hardware::build(board)
    }

    /// Fixed-area application of new (local-memory bandwidth, NoC
    /// bandwidth, local latency) choices: the per-core area budget is this
    /// baseline's, and the systolic array shrinks to whatever still fits
    /// next to the re-banked local memory (§7.3.2 trade-off).
    pub fn with_fixed_area(
        &self,
        lmem_bw: f64,
        noc_bw: f64,
        lmem_lat: u64,
        area: &AreaModel,
    ) -> DmcParams {
        let budget = area.dmc_core(
            self.lmem_capacity,
            self.lmem_bandwidth,
            self.systolic,
            self.vector_lanes,
        );
        let n = area.max_systolic_under(budget, self.lmem_capacity, lmem_bw, self.vector_lanes);
        DmcParams {
            lmem_bandwidth: lmem_bw,
            noc_bandwidth: noc_bw,
            lmem_latency: lmem_lat,
            systolic: (n.max(8), n.max(8)),
            ..self.clone()
        }
    }

    /// Chip area breakdown: (cores, control, interconnect, total) in mm².
    pub fn area(&self, model: &AreaModel) -> (f64, f64, f64, f64) {
        let cores = self.cores() as f64
            * model.dmc_core(
                self.lmem_capacity,
                self.lmem_bandwidth,
                self.systolic,
                self.vector_lanes,
            );
        let (ctrl, ic, total) = model.chip_total(cores);
        (cores, ctrl, ic, total)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hwir::mlc;

    #[test]
    fn default_build_shape() {
        let hw = DmcParams::default().build();
        assert_eq!(hw.points_of_kind("compute").len(), 128);
        assert_eq!(hw.points_of_kind("dram").len(), 1);
        assert_eq!(hw.points_of_kind("comm").len(), 2); // noc + phy
        // core addressable at board(0) -> (r, c)
        assert!(hw.cell(&mlc(&[&[0], &[15, 7]])).is_some());
        assert!(hw.cell(&mlc(&[&[0], &[16, 0]])).is_none());
    }

    #[test]
    fn without_dram() {
        let p = DmcParams {
            with_dram: false,
            ..Default::default()
        };
        let hw = p.build();
        assert!(hw.points_of_kind("dram").is_empty());
    }

    #[test]
    fn table2_configs_distinct_and_total_memory() {
        let c2 = DmcParams::table2(2).unwrap();
        assert_eq!(c2.total_lmem(), 256 << 20); // 2MB * 128 = 256MB
        let c3 = DmcParams::table2(3).unwrap();
        assert_eq!(c3.total_lmem(), 320 << 20); // 2.5MB * 128 = 320MB (IPU-like)
        for i in 1..=4 {
            for j in i + 1..=4 {
                assert_ne!(DmcParams::table2(i).unwrap(), DmcParams::table2(j).unwrap());
            }
        }
    }

    #[test]
    fn dram_route_crosses_levels() {
        let hw = DmcParams::default().build();
        let segs = hw.route(&mlc(&[&[0], &[3, 4]]), &mlc(&[&[1]]));
        assert_eq!(segs.len(), 2); // noc then phy
        assert_eq!(hw.point(segs[0].comm).name, "noc");
        assert_eq!(hw.point(segs[1].comm).name, "phy");
        assert_eq!(segs[0].hops, 7); // (3,4) -> (0,0) port
    }

    #[test]
    fn area_monotone_in_systolic() {
        let m = AreaModel::default();
        let small = DmcParams::table2(4).unwrap().area(&m).3;
        let big = DmcParams::table2(1).unwrap().area(&m).3;
        assert!(big > small);
    }

    #[test]
    fn table2_out_of_range_is_an_error() {
        for bad in [0usize, 5, 99] {
            let err = DmcParams::table2(bad).unwrap_err();
            let msg = format!("{err:#}");
            assert!(msg.contains("out of range"), "unexpected message: {msg}");
        }
    }
}
