//! Regression tests for the declarative hardware-spec round trip:
//! `hwir::parse_spec` → `hwir::to_spec` → `hwir::parse_spec` must be a
//! fixed point for nested matrices, `fill` cells, holes, sync groups and
//! evaluator bindings — and malformed input must fail loudly at the right
//! layer (`util::json` for syntax, `SpecError` for structure).

use mldse::hwir::{parse_spec, to_spec, Hardware, SpaceMatrix};
use mldse::util::json::Json;

/// A deliberately gnarly spec: three levels, a heterogeneous override, a
/// hole, a comm point with an evaluator binding, and both kinds of sync
/// group (explicit members and all-cells).
const NESTED: &str = r#"{
  "matrix": {
    "name": "board", "dims": [2, 2],
    "comms": [{"name": "bnet", "topology": "ring",
               "link_bandwidth": 8, "link_latency": 16,
               "evaluator": "pjrt"}],
    "fill": {"matrix": {
      "name": "chip", "dims": [3],
      "comms": [{"name": "noc", "topology": "mesh",
                 "link_bandwidth": 32, "link_latency": 1}],
      "fill": {"point": {"name": "core", "kind": "compute",
               "systolic": [16, 16], "vector_lanes": 64,
               "lmem": {"capacity": 2097152, "bandwidth": 152,
                        "latency": 2}}},
      "cells": [{"at": [2], "point": {"name": "sram", "kind": "memory",
                 "capacity": 8388608, "bandwidth": 128, "latency": 4}}],
      "sync_groups": [{"name": "cores", "members": [[0], [1]]}]
    }},
    "cells": [
      {"at": [1, 1], "hole": true},
      {"at": [0, 1], "point": {"name": "hbm", "kind": "dram",
       "capacity": 17179869184, "bandwidth": 2048, "latency": 100,
       "evaluator": "dramsim"}}
    ],
    "sync_groups": [{"name": "everything", "members": null}]
  }
}"#;

fn assert_same_hardware(a: &SpaceMatrix, b: &SpaceMatrix) {
    let ha = Hardware::build(a.clone());
    let hb = Hardware::build(b.clone());
    assert_eq!(ha.num_points(), hb.num_points());
    for (ea, eb) in ha.entries().zip(hb.entries()) {
        assert_eq!(ea.addr, eb.addr);
        assert_eq!(ea.point, eb.point);
        assert_eq!(ea.level, eb.level);
    }
    assert_eq!(ha.sync_groups().len(), hb.sync_groups().len());
    for (ga, gb) in ha.sync_groups().iter().zip(hb.sync_groups()) {
        assert_eq!(ga.name, gb.name);
        assert_eq!(ga.matrix, gb.matrix);
        assert_eq!(ga.points, gb.points);
    }
}

#[test]
fn nested_spec_roundtrips_compact_and_pretty() {
    let m = parse_spec(NESTED).unwrap();
    // Parsed shape: the 2x2 board fill stamps a chip everywhere, then the
    // overrides punch a hole at (1,1) and a DRAM at (0,1) -> 2 chips of
    // 3 cells each (one overridden to a memory), 1 dram.
    let hw = Hardware::build(m.clone());
    assert_eq!(hw.points_of_kind("compute").len(), 4);
    assert_eq!(hw.points_of_kind("memory").len(), 2);
    assert_eq!(hw.points_of_kind("dram").len(), 1);
    assert_eq!(hw.points_of_kind("comm").len(), 3); // bnet + 2 nocs

    let compact = to_spec(&m).to_string();
    let m2 = parse_spec(&compact).unwrap();
    assert_same_hardware(&m, &m2);

    let pretty = to_spec(&m).to_pretty();
    let m3 = parse_spec(&pretty).unwrap();
    assert_same_hardware(&m, &m3);
}

#[test]
fn serializer_is_idempotent_after_first_materialization() {
    // `fill` is materialized into explicit cells on the first parse, so
    // from the second round on, the textual form must be a fixed point.
    let m1 = parse_spec(NESTED).unwrap();
    let text1 = to_spec(&m1).to_string();
    let m2 = parse_spec(&text1).unwrap();
    let text2 = to_spec(&m2).to_string();
    assert_eq!(text1, text2);
}

#[test]
fn evaluator_bindings_survive_roundtrip() {
    let m = parse_spec(NESTED).unwrap();
    let m2 = parse_spec(&to_spec(&m).to_string()).unwrap();
    let hw = Hardware::build(m2);
    let dram = hw.points_of_kind("dram")[0];
    assert_eq!(hw.point(dram).evaluator, "dramsim");
    let bnet = hw.comm(&mldse::hwir::MlCoord::root(), 0).unwrap();
    assert_eq!(hw.point(bnet).evaluator, "pjrt");
}

#[test]
fn holes_and_sync_groups_survive_roundtrip() {
    let m = parse_spec(NESTED).unwrap();
    let m2 = parse_spec(&to_spec(&m).to_string()).unwrap();
    let hw = Hardware::build(m2);
    // the (1,1) hole stays a hole
    assert!(hw.retrieve(&mldse::hwir::mlc(&[&[1, 1]])).is_none());
    // all-cells group resolves over every populated cell's subtree
    let all = hw.sync_group("everything").unwrap();
    assert_eq!(all.points.len(), hw.num_points() - 1); // minus board's bnet
    // explicit-member group resolved per chip
    let cores = hw.sync_group("cores").unwrap();
    assert_eq!(cores.points.len(), 2);
}

#[test]
fn fill_only_spec_roundtrips() {
    let spec = r#"{
      "matrix": {
        "name": "chip", "dims": [2, 3],
        "fill": {"point": {"name": "core", "kind": "compute",
                 "systolic": [8, 8], "vector_lanes": 16}}
      }
    }"#;
    let m = parse_spec(spec).unwrap();
    let m2 = parse_spec(&to_spec(&m).to_string()).unwrap();
    assert_same_hardware(&m, &m2);
    assert_eq!(Hardware::build(m2).points_of_kind("compute").len(), 6);
}

// ----------------------------------------------------------------------
// Malformed input: JSON syntax layer (util::json directly)
// ----------------------------------------------------------------------

#[test]
fn json_syntax_errors_carry_offsets() {
    for bad in [
        "",
        "{",
        r#"{"matrix""#,
        r#"{"matrix": }"#,
        r#"{"matrix": {"dims": [2,]}}"#,
        r#"{"a": "unterminated}"#,
        r#"{"a": 1} trailing"#,
        r#"{"a": 01x}"#,
        "{\"a\": \"bad\\escape\"}",
    ] {
        let err = Json::parse(bad).unwrap_err();
        assert!(
            err.offset <= bad.len(),
            "offset {} beyond input len {} for {bad:?}",
            err.offset,
            bad.len()
        );
        assert!(!err.message.is_empty());
        // and the spec layer surfaces the same failure as a SpecError
        assert!(parse_spec(bad).is_err(), "spec accepted bad JSON {bad:?}");
    }
}

#[test]
fn json_unicode_escape_errors() {
    assert!(Json::parse(r#""\u12""#).is_err()); // truncated escape
    assert!(Json::parse(r#""\ud800""#).is_err()); // unpaired surrogate
    assert!(Json::parse(r#""\ud800A""#).is_err()); // bad low surrogate
    assert_eq!(
        Json::parse(r#""😀""#).unwrap().as_str(),
        Some("😀")
    );
}

// ----------------------------------------------------------------------
// Malformed input: spec structure layer
// ----------------------------------------------------------------------

#[test]
fn structurally_invalid_specs_are_rejected() {
    let cases: &[(&str, &str)] = &[
        ("{}", "missing matrix"),
        (r#"{"matrix": {"name": "x"}}"#, "missing dims"),
        (r#"{"matrix": {"dims": []}}"#, "empty dims"),
        (r#"{"matrix": {"dims": [0]}}"#, "zero dim"),
        (r#"{"matrix": {"dims": [1.5]}}"#, "fractional dim"),
        (
            r#"{"matrix": {"dims": [1], "fill": {"point": {"kind": "warp"}}}}"#,
            "unknown point kind",
        ),
        (
            r#"{"matrix": {"dims": [1], "fill": {"point": {"name": "m", "kind": "memory"}}}}"#,
            "memory without capacity",
        ),
        (
            r#"{"matrix": {"dims": [1], "fill": {"wat": 1}}}"#,
            "element without point/matrix",
        ),
        (
            r#"{"matrix": {"dims": [2], "cells": [{"point": {"kind": "compute"}}]}}"#,
            "cell without at",
        ),
        (
            r#"{"matrix": {"dims": [2], "cells": [{"at": [9], "point": {"kind": "compute"}}]}}"#,
            "cell out of shape",
        ),
        (
            r#"{"matrix": {"dims": [1], "comms": [{"link_bandwidth": 8}]}}"#,
            "comm without topology",
        ),
        (
            r#"{"matrix": {"dims": [1], "comms": [{"topology": "hypercube", "link_bandwidth": 8}]}}"#,
            "unknown topology",
        ),
        (
            r#"{"matrix": {"dims": [1], "comms": [{"topology": "bus"}]}}"#,
            "comm without bandwidth",
        ),
        (
            r#"{"matrix": {"dims": [2], "sync_groups": [{"members": [[0]]}]}}"#,
            "sync group without name",
        ),
        (
            r#"{"matrix": {"dims": [2], "sync_groups": [{"name": "g", "members": [0]}]}}"#,
            "sync member not a coord",
        ),
        (
            r#"{"matrix": {"dims": [2], "sync_groups": [{"name": "g", "members": "all"}]}}"#,
            "sync members wrong type",
        ),
    ];
    for (spec, why) in cases {
        assert!(parse_spec(spec).is_err(), "accepted invalid spec ({why})");
    }
}

#[test]
fn spec_error_messages_name_the_offender() {
    let err = parse_spec(r#"{"matrix": {"name": "widget"}}"#).unwrap_err();
    assert!(err.to_string().contains("widget"), "got: {err}");
    let err = parse_spec(
        r#"{"matrix": {"dims": [1], "comms": [{"name": "warpnet",
            "topology": "warp", "link_bandwidth": 1}]}}"#,
    )
    .unwrap_err();
    assert!(err.to_string().contains("warp"), "got: {err}");
}
