//! Deterministic PRNG (PCG-XSH-RR 64/32) + helpers.
//!
//! All stochastic behaviour in the DSE engine (random search, annealing,
//! workload jitter) flows through [`Pcg`] so a fixed seed reproduces a run
//! bit-for-bit — a requirement for the hardware-consistent scheduler tests.

/// PCG-XSH-RR 64/32 generator (O'Neill 2014).
#[derive(Debug, Clone)]
pub struct Pcg {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg {
    /// Create a generator from a seed; distinct seeds give independent
    /// streams.
    pub fn new(seed: u64) -> Self {
        let mut rng = Pcg {
            state: 0,
            inc: (seed << 1) | 1,
        };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(0x853c49e6748fea9b ^ seed);
        rng.next_u32();
        rng
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, bound)`; rejects modulo bias.
    pub fn below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "below(0)");
        let threshold = bound.wrapping_neg() % bound;
        loop {
            let r = self.next_u64();
            if r >= threshold {
                return r % bound;
            }
        }
    }

    /// Uniform usize in `[0, bound)`.
    pub fn index(&mut self, bound: usize) -> usize {
        self.below(bound as u64) as usize
    }

    /// Uniform in `[lo, hi]` (inclusive).
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Bernoulli trial.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Pick a uniformly random element of a slice.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.index(items.len())]
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.index(i + 1);
            items.swap(i, j);
        }
    }

    /// Split off an independent child stream (for per-worker determinism).
    pub fn split(&mut self) -> Pcg {
        Pcg::new(self.next_u64())
    }

    /// Derive an independent *named* stream from this generator's current
    /// state without advancing it. Unlike [`Pcg::split`] — which consumes
    /// a draw, so the order of splits matters — `fork` is a pure function
    /// of `(state, increment, stream_id)`: the same name always yields the
    /// same stream, different names yield independent streams, and the
    /// parent continues exactly as if `fork` had never been called. This
    /// is how subsystems (surrogate training, ranking jitter) derive their
    /// randomness from the session seed without perturbing the explorer's
    /// stream or depending on call order across worker counts.
    pub fn fork(&self, stream_id: &str) -> Pcg {
        // FNV-1a over the stream name, folded with both halves of the
        // generator state so distinct parents give distinct children.
        let mut h = 0xcbf29ce484222325u64;
        for b in stream_id.as_bytes() {
            h = (h ^ *b as u64).wrapping_mul(0x100000001b3);
        }
        Pcg::new(h ^ self.state.rotate_left(17) ^ self.inc.rotate_left(43))
    }

    /// Export the raw generator state `(state, increment)` for
    /// serialization (exploration checkpoints). [`Pcg::from_parts`]
    /// restores a generator that continues the stream bit-for-bit.
    pub fn to_parts(&self) -> (u64, u64) {
        (self.state, self.inc)
    }

    /// Rebuild a generator from [`Pcg::to_parts`] output. The restored
    /// stream is indistinguishable from the original — no reseeding, no
    /// warm-up draws.
    pub fn from_parts(state: u64, inc: u64) -> Pcg {
        Pcg { state, inc }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Pcg::new(42);
        let mut b = Pcg::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Pcg::new(1);
        let mut b = Pcg::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn below_respects_bound() {
        let mut rng = Pcg::new(7);
        for _ in 0..1000 {
            assert!(rng.below(13) < 13);
        }
    }

    #[test]
    fn f64_in_unit_interval_and_roughly_uniform() {
        let mut rng = Pcg::new(3);
        let n = 10_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let v = rng.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg::new(11);
        let mut v: Vec<u32> = (0..50).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn split_streams_independent() {
        let mut rng = Pcg::new(5);
        let mut c1 = rng.split();
        let mut c2 = rng.split();
        assert_ne!(c1.next_u64(), c2.next_u64());
    }

    #[test]
    fn fork_streams_are_named_independent_and_leave_the_parent_untouched() {
        let mut parent = Pcg::new(0xD5E);
        for _ in 0..5 {
            parent.next_u64();
        }
        let before = parent.to_parts();

        // same name → same stream; different names → independent streams
        let mut a1 = parent.fork("surrogate-train");
        let mut a2 = parent.fork("surrogate-train");
        let mut b = parent.fork("surrogate-rank");
        let same = (0..64).filter(|_| a1.next_u64() == b.next_u64()).count();
        assert!(same < 4, "named streams must be independent");
        let mut a1 = parent.fork("surrogate-train");
        for _ in 0..64 {
            assert_eq!(a1.next_u64(), a2.next_u64());
        }

        // forking never advances the parent
        assert_eq!(parent.to_parts(), before);
        let mut control = Pcg::from_parts(before.0, before.1);
        for _ in 0..32 {
            assert_eq!(parent.next_u64(), control.next_u64());
        }
    }

    #[test]
    fn fork_is_stable_across_worker_like_interleavings() {
        // Two "processes" that reach the same parent state by different
        // call orders derive identical named streams — the property that
        // keeps surrogate randomness bit-identical across worker counts.
        let w1 = Pcg::new(42);
        let w2 = Pcg::new(42);
        let mut s1 = w1.fork("rank");
        let _ = w2.fork("train"); // extra fork in between must not matter
        let mut s2 = w2.fork("rank");
        for _ in 0..64 {
            assert_eq!(s1.next_u64(), s2.next_u64());
        }
        // and distinct parents give distinct children under the same name
        let mut other = Pcg::new(43).fork("rank");
        let mut s3 = Pcg::new(42).fork("rank");
        let same = (0..64).filter(|_| other.next_u64() == s3.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn parts_roundtrip_continues_stream() {
        let mut rng = Pcg::new(0xD5E);
        for _ in 0..17 {
            rng.next_u64();
        }
        let (state, inc) = rng.to_parts();
        let mut restored = Pcg::from_parts(state, inc);
        for _ in 0..100 {
            assert_eq!(rng.next_u64(), restored.next_u64());
        }
    }

    #[test]
    fn range_inclusive() {
        let mut rng = Pcg::new(9);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..2000 {
            let v = rng.range_u64(3, 6);
            assert!((3..=6).contains(&v));
            seen_lo |= v == 3;
            seen_hi |= v == 6;
        }
        assert!(seen_lo && seen_hi);
    }
}
